"""Regenerate the tables of EXPERIMENTS.md from results/*.json.

    PYTHONPATH=src:. python -m benchmarks.experiments_report > tables.md
"""
from __future__ import annotations

import json
import os

RES = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    try:
        with open(os.path.join(RES, name)) as f:
            return json.load(f)
    except FileNotFoundError:
        return None


def fmt_s(x):
    if x >= 1:
        return f"{x:8.2f}"
    if x >= 1e-3:
        return f"{x * 1e3:6.1f}m"
    return f"{x * 1e6:6.0f}u"


def dryrun_table(mesh="single", path="dryrun.json"):
    rows = _load(path) or []
    rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | status | dominant | t_comp (s) | t_mem (s) | "
           "t_coll (s) | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                       f"{r.get('reason', r.get('error', ''))[:40]} | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['dominant_term']} | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | "
            f"{(r.get('useful_flops_ratio') or 0):.3f} |")
    return "\n".join(out)


def table2():
    rows = _load("table2.json") or []
    out = ["| setting | scheme | days to 40% | best acc | updates | "
           "aggregated | idle / total |", "|---|---|---|---|---|---|---|"]
    for r in rows:
        d = r["days_to_target"]
        out.append(
            f"| {r['setting']} | {r['scheme']} | "
            f"{d if d is not None else 'FAIL'} | {r['best_acc']:.3f} | "
            f"{r['global_updates']} | {r['aggregated_gradients']} | "
            f"{r['idle_connections']} / {r['total_connections']} |")
    return "\n".join(out)


def main():
    print("## Dry-run + roofline (single pod, 16x16 = 256 chips)\n")
    print(dryrun_table("single"))
    print("\n## Dry-run (multi-pod, 2x16x16 = 512 chips)\n")
    print(dryrun_table("multi"))
    print("\n## Perf variants (results/dryrun_perf.json)\n")
    print(dryrun_table("single", "dryrun_perf.json"))
    print(dryrun_table("multi", "dryrun_perf.json"))
    print("\n## Table 2 reproduction\n")
    print(table2())


if __name__ == "__main__":
    main()
