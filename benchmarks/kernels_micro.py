"""Kernel microbenchmarks: wall-time per call of each Pallas kernel
(interpret mode on CPU — correctness-path timing; TPU is the perf target)
vs its pure-jnp oracle, over representative shapes. Emits
name,us_per_call,derived CSV rows."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.agg.kernel import weighted_aggregate
from repro.kernels.agg.ref import weighted_aggregate_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _time(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    key = jax.random.PRNGKey(0)
    out = []

    # aggregation: M=191 updates over a 1M-param model slice
    M, N = 191, 1_000_000
    upd = jax.random.normal(key, (M, N), jnp.float32)
    p = jnp.zeros((N,), jnp.float32)
    w = jnp.full((M,), 1.0 / M)
    t_k = _time(weighted_aggregate, p, upd, w, interpret=True)
    t_r = _time(weighted_aggregate_ref, p, upd, w)
    out.append(("kernel_agg_m191_n1m_interpret", t_k,
                f"bytes={(M + 2) * N * 4 / 1e6:.0f}MB"))
    out.append(("ref_agg_m191_n1m", t_r, "jnp_oracle"))

    # rmsnorm: (4096, 4096)
    x = jax.random.normal(key, (4096, 4096), jnp.bfloat16)
    s = jnp.ones((4096,), jnp.bfloat16)
    out.append(("kernel_rmsnorm_4kx4k_interpret",
                _time(rmsnorm, x, s, interpret=True), "rows=256"))
    out.append(("ref_rmsnorm_4kx4k", _time(rmsnorm_ref, x, s), "jnp_oracle"))

    # flash attention: B1 H8 S1024 hd128 causal
    q = jax.random.normal(key, (1, 8, 1024, 128), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 8, 1024, 128))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 8, 1024, 128))
    out.append(("kernel_flash_s1024_interpret",
                _time(flash_attention, q, k, v, causal=True, bq=256, bk=256,
                      interpret=True, iters=1), "causal"))
    out.append(("ref_attention_s1024",
                _time(attention_ref, q, k, v, causal=True), "jnp_oracle"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
