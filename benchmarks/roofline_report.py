"""§Roofline report: read results/dryrun.json (written by the dry-run sweep)
and emit the per-(arch x shape x mesh) three-term roofline table with the
dominant bottleneck and the MODEL_FLOPS / HLO_FLOPs usefulness ratio."""
from __future__ import annotations

import argparse
import json
import os

COLS = ("arch", "shape", "mesh", "dominant_term", "t_compute_s",
        "t_memory_s", "t_collective_s", "useful_flops_ratio")


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt_row(r):
    if r["status"] == "skipped":
        return (f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:6s} "
                f"SKIP ({r.get('reason', '')[:48]})")
    if r["status"] != "ok":
        return (f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:6s} "
                f"ERROR {r.get('error', '')[:60]}")
    ufr = r.get("useful_flops_ratio")
    return (f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['dominant_term']:10s} "
            f"c={r['t_compute_s']:9.3e} m={r['t_memory_s']:9.3e} "
            f"x={r['t_collective_s']:9.3e} useful={ufr:6.3f}" if ufr else "")


def summarize(rows):
    ok = [r for r in rows if r["status"] == "ok"]
    by_dom = {}
    for r in ok:
        by_dom.setdefault(r["dominant_term"], []).append(
            (r["arch"], r["shape"], r["mesh"]))
    return by_dom


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=os.path.join(
        os.path.dirname(__file__), "..", "results", "dryrun.json"))
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load(args.path)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    for r in rows:
        if args.mesh and r["mesh"] != args.mesh:
            continue
        print(fmt_row(r))
    dom = summarize(rows)
    print("\ndominant-term counts:",
          {k: len(v) for k, v in dom.items()})


if __name__ == "__main__":
    main()
