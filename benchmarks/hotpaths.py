"""Hot-path benchmark: the two simulation bottlenecks, seed path vs
vectorized path, with machine-readable output.

1. **Schedule-search re-plan** (eq. 13): one `fedspace_search` call at the
   paper's shapes — `num_candidates` schedules over an I0-window horizon,
   every (candidate, window) histogram scored by the utility forest. The
   seed path walks forest nodes per row in pure Python and featurizes on
   host; the optimized path runs structure-of-arrays forest inference
   on-device with jnp featurization (no host round-trip after the protocol
   simulator).
2. **Aggregation round** (eq. 4): one `on_aggregate` with a buffer of
   satellite updates. The seed path dispatched one jitted client update
   per satellite, each with its own checkpoint fetch, then reduced via
   stack+tensordot; the optimized path groups satellites by base version,
   trains each group under a single vmapped jitted call, and routes the
   reduction through the aggregation kernel dispatch.

Writes results to ``BENCH_hotpaths.json`` at the repo root (``--smoke``
writes ``BENCH_hotpaths.smoke.json`` instead so CI runs never clobber the
committed baseline). Regenerate the baseline with:

    PYTHONPATH=src python -m benchmarks.hotpaths
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS
from repro.core.scheduler import make_scheduler
from repro.core.search import fedspace_search
from repro.core.staleness import staleness_compensation
from repro.core.utility import RandomForestRegressor, featurize
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition
from repro.data.pipeline import make_clients
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.compression import roundtrip
from repro.fl.engine import EngineConfig, SimulationEngine

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# 1. schedule-search re-plan


def _fit_search_regressor(s_max=8, n_trees=40, seed=0):
    """Forest over the search feature space (simulator staleness
    histograms), fitted on a synthetic count-utility curve."""
    rng = np.random.default_rng(seed)
    hists = rng.integers(0, 25, (600, s_max + 1)).astype(np.float32)
    X = featurize(hists, 1.0)
    s = np.arange(s_max + 1, dtype=np.float32)
    y = ((hists * (1.2 - 0.3 * s)).sum(1)
         / np.maximum(hists.sum(1), 1.0)
         + 0.05 * rng.normal(size=len(X))).astype(np.float32)
    return RandomForestRegressor(n_trees=n_trees, max_depth=6,
                                 seed=seed).fit(X, y)


def _seed_step(state, ig, connected, aggregate, *, s_max):
    """The seed protocol step, with the histogram built by scatter-add
    (the pre-vectorization `repro.core.staleness.step`)."""
    has_pending = state.pending >= 0
    uploads = connected & has_pending
    buffered = jnp.where(uploads, state.pending, state.buffered)
    pending = jnp.where(uploads, -1, state.pending)
    idle = connected & (~has_pending) & (state.version == ig)
    n_idle = jnp.sum(idle.astype(jnp.int32))
    in_buffer = buffered >= 0
    aggregate = jnp.logical_and(aggregate, jnp.any(in_buffer))
    stale = jnp.where(in_buffer, ig - buffered, 0)
    stale_c = jnp.clip(stale, 0, s_max)
    hist = jnp.zeros((s_max + 1,), jnp.int32).at[stale_c].add(
        (in_buffer & aggregate).astype(jnp.int32))
    n_agg = jnp.sum((in_buffer & aggregate).astype(jnp.int32))
    max_stale = jnp.max(jnp.where(in_buffer & aggregate, stale, 0))
    new_ig = ig + aggregate.astype(jnp.int32)
    buffered = jnp.where(aggregate, -1, buffered)
    gets_new = connected & (state.version < new_ig)
    version = jnp.where(gets_new, new_ig, state.version)
    pending = jnp.where(gets_new, new_ig, pending)
    info = {"hist": hist, "n_aggregated": n_agg, "n_idle": n_idle,
            "max_staleness": max_stale}
    return SS.SatState(version, pending, buffered), new_ig, info


def _seed_replan(rng, C, state, ig, rf, status, *, num_candidates, s_max):
    """The seed re-plan pipeline end-to-end: scatter-add protocol
    simulator, hist to host, host featurize, pure-Python node-walk forest.
    (Candidate selection uses the shared `select_candidate` rule so the
    before/after comparison isolates the scoring pipeline.)"""
    from repro.core.search import random_candidates, select_candidate
    I0 = C.shape[0]
    cands = random_candidates(rng, I0, 4, 8, num_candidates)

    def sim_window(a):
        def body(carry, inp):
            st, g = carry
            c, ai = inp
            st, g, info = _seed_step(st, g, c, ai.astype(bool),
                                     s_max=s_max)
            return (st, g), info
        (st, g), infos = jax.lax.scan(
            body, (state, jnp.int32(ig)),
            (jnp.asarray(C), a.astype(jnp.int32)))
        return st, g, infos

    _, _, infos = jax.vmap(sim_window)(jnp.asarray(cands))
    hist = np.asarray(infos["hist"])
    Rn, I0_, F = hist.shape
    feats = featurize(hist.reshape(Rn * I0_, F), status)
    util = rf.predict_reference(feats).reshape(Rn, I0_)
    scores = (util * cands.astype(np.float32)).sum(axis=1)
    return cands[select_candidate(cands, scores)]


def bench_search(smoke: bool) -> dict:
    K = 16 if smoke else 191          # fig.-2 constellation scale
    R = 64 if smoke else 5000         # |R| from the paper
    I0 = 8 if smoke else 24
    s_max = 8
    rng = np.random.default_rng(0)
    C = rng.random((I0, K)) < 0.15
    state = SS.bootstrap_state(K)
    rf = _fit_search_regressor(s_max=s_max)

    def replan_opt():
        t0 = time.perf_counter()
        sched = fedspace_search(np.random.default_rng(7), C, state, 0, rf,
                                1.0, num_candidates=R, s_max=s_max)
        return time.perf_counter() - t0, sched

    def replan_ref():
        t0 = time.perf_counter()
        sched = _seed_replan(np.random.default_rng(7), C, state, 0, rf,
                             1.0, num_candidates=R, s_max=s_max)
        return time.perf_counter() - t0, sched

    # both paths: one cold run (pays jit compile), then min-of-3 warm runs
    # (matching how re-plans recur every I0 windows)
    t_opt_cold, sched_opt = replan_opt()
    t_opt_warm = min(replan_opt()[0] for _ in range(3))
    _, sched_ref = replan_ref()
    t_ref = min(replan_ref()[0] for _ in range(3))

    return {
        "num_candidates": R, "I0": I0, "K": K,
        "n_trees": rf.n_trees, "max_depth": rf.max_depth,
        "rows_scored": R * I0,
        "t_reference_s": t_ref,
        "t_optimized_cold_s": t_opt_cold,
        "t_optimized_warm_s": t_opt_warm,
        "speedup_cold": t_ref / t_opt_cold,
        "speedup_warm": t_ref / t_opt_warm,
        "schedule_identical": bool(np.array_equal(sched_ref, sched_opt)),
    }


# ---------------------------------------------------------------------------
# 2. aggregation round


def _seed_aggregate(eng, i: int):
    """The seed engine's `on_aggregate` hot loop (one dispatch + checkpoint
    fetch per satellite, sequential compression, stack-tensordot-add),
    without the bookkeeping; returns the new global params."""
    cfg = eng.config
    ks = np.flatnonzero(eng.buffered_base >= 0)
    stal = eng.ig - eng.buffered_base[ks]
    updates = []
    for k in ks:
        base = eng.store.get(int(eng.buffered_base[k]))
        u = eng._client_update(base, int(k), round_rng=i,
                               batch_size=cfg.batch_size)
        if cfg.uplink_topk > 0.0:
            u, _ = roundtrip(u, cfg.uplink_topk)
        updates.append(u)
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
    c = staleness_compensation(jnp.asarray(stal), cfg.alpha)
    w = c / jnp.maximum(jnp.sum(c), 1e-12) * cfg.server_lr
    delta = jax.tree.map(
        lambda u_: jnp.tensordot(w.astype(jnp.float32),
                                 u_.astype(jnp.float32), axes=1), stack)
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
        eng.params, delta)


def _batched_aggregate(eng, i: int):
    """The optimized path (`SimulationEngine.on_aggregate` compute body)."""
    from repro.core.aggregation import aggregation_weights
    from repro.kernels.agg.ops import aggregate_params_tree
    cfg = eng.config
    ks = np.flatnonzero(eng.buffered_base >= 0)
    stal = eng.ig - eng.buffered_base[ks]
    stack = eng._train_buffered(ks, round_rng=i)
    w = aggregation_weights(jnp.asarray(stal), cfg.alpha) * cfg.server_lr
    return aggregate_params_tree(eng.params, stack, w)


def _block(params):
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, params)


def bench_aggregation(smoke: bool) -> dict:
    K = 8 if smoke else 191           # buffered satellites per round
    num_train = 400 if smoke else 7640
    n_versions = 2 if smoke else 4    # distinct base versions in buffer
    hidden = 64
    reps = 2 if smoke else 5
    data = SyntheticFmow(FmowSpec(num_train=num_train, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(
        iid_partition(num_train, K, 0)), hidden=hidden)
    C = np.ones((4, K), bool)
    eng = SimulationEngine(C, adapter, make_scheduler("async"),
                           EngineConfig())
    eng.prepare()
    # a buffer where every satellite holds an update, spread over
    # n_versions base versions (stale + fresh mix, as under FedSpace)
    rng = np.random.default_rng(0)
    for v in range(1, n_versions):
        eng.store.put(v, eng.params)
    eng.ig = n_versions - 1
    eng.buffered_base[:] = rng.integers(0, n_versions, K)
    eng.version[:] = eng.ig

    def timed(fn):
        fn(eng, 3)                    # warm the jit caches
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn(eng, 3)
            _block(out)
            ts.append(time.perf_counter() - t0)
        return min(ts), out

    t_opt, p_opt = timed(_batched_aggregate)
    t_ref, p_ref = timed(_seed_aggregate)
    bit_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_opt)))

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        eng.params))
    return {
        "n_buffered": K, "n_base_versions": n_versions,
        "model_params": n_params, "local_steps": eng.config.local_steps,
        "t_reference_s": t_ref,
        "t_batched_s": t_opt,
        "speedup": t_ref / t_opt,
        "params_bit_equal": bool(bit_equal),
    }


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI harness-rot check)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_hotpaths.json, or BENCH_hotpaths.smoke.json "
                         "with --smoke)")
    args = ap.parse_args()

    out_path = args.out or os.path.join(
        _ROOT, "BENCH_hotpaths.smoke.json" if args.smoke
        else "BENCH_hotpaths.json")

    t0 = time.time()
    print(f"# hot-path benchmark (smoke={args.smoke}) on "
          f"{jax.default_backend()}", flush=True)
    search = bench_search(args.smoke)
    print(f"search_replan: reference {search['t_reference_s']:.3f}s, "
          f"optimized warm {search['t_optimized_warm_s']:.3f}s "
          f"({search['speedup_warm']:.1f}x), schedule_identical="
          f"{search['schedule_identical']}", flush=True)
    agg = bench_aggregation(args.smoke)
    print(f"aggregation_round: reference {agg['t_reference_s']:.3f}s, "
          f"batched {agg['t_batched_s']:.3f}s ({agg['speedup']:.1f}x), "
          f"params_bit_equal={agg['params_bit_equal']}", flush=True)

    result = {
        "meta": {
            "smoke": args.smoke,
            "date": time.strftime("%Y-%m-%d"),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "bench_wall_s": round(time.time() - t0, 2),
        },
        "search_replan": search,
        "aggregation_round": agg,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"# wrote {out_path} ({result['meta']['bench_wall_s']}s total)")

    if not (search["schedule_identical"] and agg["params_bit_equal"]):
        raise SystemExit("parity violation — see JSON output")


if __name__ == "__main__":
    main()
