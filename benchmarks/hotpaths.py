"""Hot-path benchmark: the simulation bottlenecks, seed path vs
vectorized/device-resident path, with machine-readable output.

1. **Schedule-search re-plan** (eq. 13): one `fedspace_search` call at the
   paper's shapes — `num_candidates` schedules over an I0-window horizon,
   every (candidate, window) histogram scored by the utility forest. The
   seed path walks forest nodes per row in pure Python and featurizes on
   host; the optimized path runs structure-of-arrays forest inference
   on-device with jnp featurization (no host round-trip after the protocol
   simulator).
2. **Aggregation round** (eq. 4): one `on_aggregate` with a buffer of
   satellite updates. The seed path dispatched one jitted client update
   per satellite, each with its own checkpoint fetch, then reduced via
   stack+tensordot; the optimized path groups satellites by base version,
   trains each group under a single vmapped jitted call, and routes the
   reduction through the aggregation kernel dispatch.
3. **Window loop** (Algorithm 1): the engine's protocol loop at
   K ∈ {34, 191, 1000}. The seed path kept per-satellite state in numpy
   and rebuilt a device SatState for the scheduler every window; the
   device-resident engine holds SatState on device and advances whole
   chunks of windows per jitted scan (`repro.fl.engine._scan_windows`),
   with a parity check of every protocol counter and the final state.
4. **Utility sampler** (eq. 12): `generate_utility_samples` per-sample
   loop vs the vectorized path (client updates grouped by base checkpoint
   and vmapped, perturbed checkpoints evaluated in vmapped loss calls).
5. **Search scaling** (mega-constellations): the full re-plan across the
   constellation scenario suite — K ∈ {40, 191, 400, 1000} satellites
   (starlink40 / flock191 / starlink400 / starlink1000 presets) x
   R ∈ {5000, 20000} candidates. The PR-3 pipeline (per-step histogram
   broadcast inside the vmapped scan, û over all R*I0 windows) is
   transcribed below as the frozen reference; the current path scans
   scatter-free int16 state emitting compact staleness marks and
   evaluates û only at each candidate's aggregation windows. Selected
   schedules must be identical cell by cell.
6. **Link budget** (capacity-constrained transfers): (a) the parity gate —
   an engine run under the trivial budget (unlimited station capacity,
   zero-latency transfers) must reproduce the geometry-only trajectory
   bit-for-bit, and the link-gated schedule search must select the
   identical schedule under the zero-need gate; (b) the downlink-capacity
   study the scenario suite was built for — the same constellation over
   `dense12` vs `sparse1` ground networks under finite rates and
   per-station capacity, reporting idle/blocked/staleness statistics that
   geometry-only contact models cannot distinguish.

7. **Inter-satellite links** (ISL subsystem): (a) the parity gate — the
   degenerate identity topology (all self-loops) run through the sink
   scheduler must reproduce the ground-only fedbuff trajectory
   bit-for-bit under both engine strategies; (b) the idle-time study —
   the sparse-ground starlink40 preset under a finite link budget,
   FedSpace / fedbuff vs the intra-plane sink scheduler and ISL gossip,
   gated on sink relaying actually reducing the eq.-10 idle share.
8. **Fault injection** (robustness layer): (a) the parity gate — an
   all-alive fault trace must reproduce the ``faults=None`` trajectory
   bit-for-bit under both engine strategies on the geometry and
   link-budget paths; (b) the degradation study — sync / fedbuff /
   fedspace / intra-plane on starlink40 over dense12 under *blind*
   satellite churn, a total station blackout, and weather-degraded
   links, gated on churn measurably reducing aggregated gradients.
9. **Real payloads** (transformer clients + compression-aware links):
   (a) the parity gate — a transformer federation (Pallas-dispatch
   forward, finite link budget) with `uplink_topk` unset, explicitly
   0.0, and under both engine strategies must produce one bit-identical
   trajectory and final model; (b) the bytes-on-the-wire study —
   starlink40 over sparse1 sweeping model family x compression ratio x
   scheduler, gated on compression cutting `need_up` and shifting the
   aggregated-gradient counts.
10. **Replan service** (incremental eq.-13 replanning): (a) the parity
   gate — at every consecutive-window request the schedule selected by
   `repro.fl.replan.ReplanService` (delta-window scoring over the cached
   scan) must be bit-identical to a full `score_candidates` +
   `select_candidate` rescan of the service's live pool, with at least
   one request answered by the delta path; (b) the latency study — warm
   delta answer time vs the full-rescan time at the serving shapes
   (K=1000 satellites, R=20000 candidates, I0=24), plus the deferred
   `maintain()` cost the delta path keeps off the answer path.

Every section registers itself in `SECTIONS`; the runner iterates the
registry and fails if a registered section is missing from the report, so
parity gates cannot rot by silent omission. Writes results to
``BENCH_hotpaths.json`` at the repo root (``--smoke`` writes
``BENCH_hotpaths.smoke.json`` instead so CI runs never clobber the
committed baseline; CI uploads the smoke report as a build artifact).
Regenerate the baseline with:

    PYTHONPATH=src python -m benchmarks.hotpaths

Run a named subset against the existing report with ``--sections``, e.g.
``python -m benchmarks.hotpaths --sections faults,isl``.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS
from repro.core.scheduler import make_scheduler
from repro.core.search import fedspace_search
from repro.core.staleness import staleness_compensation
from repro.core.utility import (RandomForestRegressor, featurize,
                                featurize_jnp)
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition
from repro.data.pipeline import make_clients
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.compression import roundtrip
from repro.fl.engine import EngineConfig, SimulationEngine

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# section registry: the runner iterates this, so a section cannot be
# silently dropped from the report (and with it, its parity gate)

SECTIONS: dict = {}    # name -> (bench_fn, parity_fn or None)


def section(name: str, parity=None):
    """Register a benchmark section. `bench_fn(smoke) -> dict` produces
    the section's report entry (and prints its own summary line);
    `parity(result) -> bool` extracts the section's parity verdict —
    any False fails the whole run with a nonzero exit."""
    def deco(fn):
        SECTIONS[name] = (fn, parity)
        return fn
    return deco


# ---------------------------------------------------------------------------
# 1. schedule-search re-plan


def _fit_search_regressor(s_max=8, n_trees=40, seed=0):
    """Forest over the search feature space (simulator staleness
    histograms), fitted on a synthetic count-utility curve."""
    rng = np.random.default_rng(seed)
    hists = rng.integers(0, 25, (600, s_max + 1)).astype(np.float32)
    X = featurize(hists, 1.0)
    s = np.arange(s_max + 1, dtype=np.float32)
    y = ((hists * (1.2 - 0.3 * s)).sum(1)
         / np.maximum(hists.sum(1), 1.0)
         + 0.05 * rng.normal(size=len(X))).astype(np.float32)
    return RandomForestRegressor(n_trees=n_trees, max_depth=6,
                                 seed=seed).fit(X, y)


def _seed_step(state, ig, connected, aggregate, *, s_max):
    """The seed protocol step, with the histogram built by scatter-add
    (the pre-vectorization `repro.core.staleness.step`)."""
    has_pending = state.pending >= 0
    uploads = connected & has_pending
    buffered = jnp.where(uploads, state.pending, state.buffered)
    pending = jnp.where(uploads, -1, state.pending)
    idle = connected & (~has_pending) & (state.version == ig)
    n_idle = jnp.sum(idle.astype(jnp.int32))
    in_buffer = buffered >= 0
    aggregate = jnp.logical_and(aggregate, jnp.any(in_buffer))
    stale = jnp.where(in_buffer, ig - buffered, 0)
    stale_c = jnp.clip(stale, 0, s_max)
    hist = jnp.zeros((s_max + 1,), jnp.int32).at[stale_c].add(
        (in_buffer & aggregate).astype(jnp.int32))
    n_agg = jnp.sum((in_buffer & aggregate).astype(jnp.int32))
    max_stale = jnp.max(jnp.where(in_buffer & aggregate, stale, 0))
    new_ig = ig + aggregate.astype(jnp.int32)
    buffered = jnp.where(aggregate, -1, buffered)
    gets_new = connected & (state.version < new_ig)
    version = jnp.where(gets_new, new_ig, state.version)
    pending = jnp.where(gets_new, new_ig, pending)
    info = {"hist": hist, "n_aggregated": n_agg, "n_idle": n_idle,
            "max_staleness": max_stale}
    return SS.SatState(version, pending, buffered), new_ig, info


def _seed_replan(rng, C, state, ig, rf, status, *, num_candidates, s_max):
    """The seed re-plan pipeline end-to-end: scatter-add protocol
    simulator, hist to host, host featurize, pure-Python node-walk forest.
    (Candidate selection uses the shared `select_candidate` rule so the
    before/after comparison isolates the scoring pipeline.)"""
    from repro.core.search import random_candidates, select_candidate
    I0 = C.shape[0]
    cands = random_candidates(rng, I0, 4, 8, num_candidates)

    def sim_window(a):
        def body(carry, inp):
            st, g = carry
            c, ai = inp
            st, g, info = _seed_step(st, g, c, ai.astype(bool),
                                     s_max=s_max)
            return (st, g), info
        (st, g), infos = jax.lax.scan(
            body, (state, jnp.int32(ig)),
            (jnp.asarray(C), a.astype(jnp.int32)))
        return st, g, infos

    _, _, infos = jax.vmap(sim_window)(jnp.asarray(cands))
    hist = np.asarray(infos["hist"])
    Rn, I0_, F = hist.shape
    feats = featurize(hist.reshape(Rn * I0_, F), status)
    util = rf.predict_reference(feats).reshape(Rn, I0_)
    scores = (util * cands.astype(np.float32)).sum(axis=1)
    return cands[select_candidate(cands, scores)]


@section("search_replan", parity=lambda r: r["schedule_identical"])
def bench_search(smoke: bool) -> dict:
    K = 16 if smoke else 191          # fig.-2 constellation scale
    R = 64 if smoke else 5000         # |R| from the paper
    I0 = 8 if smoke else 24
    s_max = 8
    rng = np.random.default_rng(0)
    C = rng.random((I0, K)) < 0.15
    state = SS.bootstrap_state(K)
    rf = _fit_search_regressor(s_max=s_max)

    def replan_opt():
        t0 = time.perf_counter()
        sched = fedspace_search(np.random.default_rng(7), C, state, 0, rf,
                                1.0, num_candidates=R, s_max=s_max)
        return time.perf_counter() - t0, sched

    def replan_ref():
        t0 = time.perf_counter()
        sched = _seed_replan(np.random.default_rng(7), C, state, 0, rf,
                             1.0, num_candidates=R, s_max=s_max)
        return time.perf_counter() - t0, sched

    # both paths: one cold run (pays jit compile), then min-of-3 warm runs
    # (matching how re-plans recur every I0 windows)
    t_opt_cold, sched_opt = replan_opt()
    t_opt_warm = min(replan_opt()[0] for _ in range(3))
    _, sched_ref = replan_ref()
    t_ref = min(replan_ref()[0] for _ in range(3))

    print(f"search_replan: reference {t_ref:.3f}s, optimized warm "
          f"{t_opt_warm:.3f}s ({t_ref / t_opt_warm:.1f}x), "
          f"schedule_identical="
          f"{bool(np.array_equal(sched_ref, sched_opt))}", flush=True)
    return {
        "num_candidates": R, "I0": I0, "K": K,
        "n_trees": rf.n_trees, "max_depth": rf.max_depth,
        "rows_scored": R * I0,
        "t_reference_s": t_ref,
        "t_optimized_cold_s": t_opt_cold,
        "t_optimized_warm_s": t_opt_warm,
        "speedup_cold": t_ref / t_opt_cold,
        "speedup_warm": t_ref / t_opt_warm,
        "schedule_identical": bool(np.array_equal(sched_ref, sched_opt)),
    }


# ---------------------------------------------------------------------------
# 1b. search scaling across the constellation scenario suite


def _pr3_replan(rng, C, state, ig, rf, status, *, num_candidates, s_max):
    """The PR-3 re-plan pipeline, transcribed: full-histogram protocol
    simulation (per-step (R, K, s_max+1) compare+reduce inside the vmapped
    scan, int32 state) and û evaluated at every one of the R*I0 windows,
    masked by the schedule afterwards. Candidate generation and selection
    are shared with the current path so the comparison isolates scoring."""
    from repro.core.search import random_candidates, select_candidate
    I0 = C.shape[0]
    cands = random_candidates(rng, I0, 4, 8, num_candidates)
    cs = jnp.asarray(cands)
    _, _, infos = SS.simulate_candidates(jnp.asarray(C), cs, state,
                                         jnp.int32(ig), s_max=s_max,
                                         lite=True)
    hist = infos["hist"]                                 # (R, I0, s_max+1)
    Rn, I0_, F = hist.shape
    feats = featurize_jnp(hist.reshape(Rn * I0_, F), status)
    util = rf.predict_device(feats).reshape(Rn, I0_)
    scores = np.asarray((util * cs.astype(jnp.float32)).sum(axis=1))
    return cands[select_candidate(cands, scores)]


@section("search_scaling",
         parity=lambda r: all(c["schedule_identical"] for c in r["cells"]))
def bench_search_scaling(smoke: bool) -> dict:
    """fedspace_search wall time over the scenario-suite grid, current
    scatter-free path vs the transcribed PR-3 pipeline, parity-gated on
    the selected schedule in every cell."""
    from repro.core.connectivity import connectivity_sets, \
        constellation_preset
    s_max = 8
    rf = _fit_search_regressor(s_max=s_max)
    if smoke:
        I0 = 8
        rng = np.random.default_rng(0)
        grid = [("random16", rng.random((I0, 16)) < 0.15, 64)]
    else:
        I0 = 24
        presets = ["starlink40", "flock191", "starlink400", "starlink1000"]
        grid = [(p, connectivity_sets(constellation_preset(p), days=0.25),
                 R) for p in presets for R in (5000, 20000)]

    out = {"I0": I0, "s_max": s_max, "n_trees": rf.n_trees, "cells": []}
    for name, C, R in grid:
        K = C.shape[1]
        state = SS.bootstrap_state(K)

        def replan_new():
            t0 = time.perf_counter()
            sched = fedspace_search(np.random.default_rng(7), C, state, 0,
                                    rf, 1.0, num_candidates=R, s_max=s_max)
            return time.perf_counter() - t0, sched

        def replan_pr3():
            t0 = time.perf_counter()
            sched = _pr3_replan(np.random.default_rng(7), C, state, 0, rf,
                                1.0, num_candidates=R, s_max=s_max)
            return time.perf_counter() - t0, sched

        t_new_cold, sched_new = replan_new()
        t_new = min(replan_new()[0] for _ in range(3))
        t_pr3_cold, sched_pr3 = replan_pr3()
        t_pr3 = min(replan_pr3()[0] for _ in range(2))
        cell = {
            "preset": name, "K": K, "num_candidates": R,
            "t_pr3_s": t_pr3,
            "t_current_s": t_new,
            "t_current_cold_s": t_new_cold,
            "speedup": t_pr3 / t_new,
            "schedule_identical": bool(np.array_equal(sched_pr3,
                                                      sched_new)),
        }
        out["cells"].append(cell)
        print(f"search_scaling {name} K={K} R={R}: pr3 {t_pr3:.3f}s, "
              f"current {t_new:.3f}s ({cell['speedup']:.1f}x), "
              f"schedule_identical={cell['schedule_identical']}",
              flush=True)
    return out


# ---------------------------------------------------------------------------
# 2. aggregation round


def _seed_aggregate(eng, i: int):
    """The seed engine's `on_aggregate` hot loop (one dispatch + checkpoint
    fetch per satellite, sequential compression, stack-tensordot-add),
    without the bookkeeping; returns the new global params."""
    cfg = eng.config
    buffered = eng.buffered_base
    ks = np.flatnonzero(buffered >= 0)
    stal = eng.ig - buffered[ks]
    updates = []
    for k in ks:
        base = eng.store.get(int(buffered[k]))
        u = eng._client_update(base, int(k), round_rng=i,
                               batch_size=cfg.batch_size)
        if cfg.uplink_topk > 0.0:
            u, _ = roundtrip(u, cfg.uplink_topk)
        updates.append(u)
    stack = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
    c = staleness_compensation(jnp.asarray(stal), cfg.alpha)
    w = c / jnp.maximum(jnp.sum(c), 1e-12) * cfg.server_lr
    delta = jax.tree.map(
        lambda u_: jnp.tensordot(w.astype(jnp.float32),
                                 u_.astype(jnp.float32), axes=1), stack)
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
        eng.params, delta)


def _batched_aggregate(eng, i: int):
    """The optimized path (`SimulationEngine.on_aggregate` compute body)."""
    from repro.core.aggregation import aggregation_weights
    from repro.kernels.agg.ops import aggregate_params_tree
    cfg = eng.config
    buffered = eng.buffered_base
    ks = np.flatnonzero(buffered >= 0)
    stal = eng.ig - buffered[ks]
    stack = eng._train_buffered(ks, buffered, round_rng=i)
    w = aggregation_weights(jnp.asarray(stal), cfg.alpha) * cfg.server_lr
    return aggregate_params_tree(eng.params, stack, w)


def _block(params):
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, params)


@section("aggregation_round", parity=lambda r: r["params_bit_equal"])
def bench_aggregation(smoke: bool) -> dict:
    K = 8 if smoke else 191           # buffered satellites per round
    num_train = 400 if smoke else 7640
    n_versions = 2 if smoke else 4    # distinct base versions in buffer
    hidden = 64
    reps = 2 if smoke else 5
    data = SyntheticFmow(FmowSpec(num_train=num_train, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(
        iid_partition(num_train, K, 0)), hidden=hidden)
    C = np.ones((4, K), bool)
    eng = SimulationEngine(C, adapter, make_scheduler("async"),
                           EngineConfig())
    eng.prepare()
    # a buffer where every satellite holds an update, spread over
    # n_versions base versions (stale + fresh mix, as under FedSpace)
    rng = np.random.default_rng(0)
    for v in range(1, n_versions):
        eng.store.put(v, eng.params)
    eng.ig = n_versions - 1
    eng.state = SS.SatState(
        jnp.full((K,), eng.ig, jnp.int32),
        jnp.asarray(eng.pending, jnp.int32),
        jnp.asarray(rng.integers(0, n_versions, K), jnp.int32))

    def timed(fn):
        fn(eng, 3)                    # warm the jit caches
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn(eng, 3)
            _block(out)
            ts.append(time.perf_counter() - t0)
        return min(ts), out

    t_opt, p_opt = timed(_batched_aggregate)
    t_ref, p_ref = timed(_seed_aggregate)
    bit_equal = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_opt)))

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        eng.params))
    print(f"aggregation_round: reference {t_ref:.3f}s, batched "
          f"{t_opt:.3f}s ({t_ref / t_opt:.1f}x), params_bit_equal="
          f"{bool(bit_equal)}", flush=True)
    return {
        "n_buffered": K, "n_base_versions": n_versions,
        "model_params": n_params, "local_steps": eng.config.local_steps,
        "t_reference_s": t_ref,
        "t_batched_s": t_opt,
        "speedup": t_ref / t_opt,
        "params_bit_equal": bool(bit_equal),
    }


# ---------------------------------------------------------------------------
# 3. window loop


class _NullAdapter:
    """Protocol-isolating adapter: tiny model, zero-gradient loss, so the
    engine's window loop is what gets measured, not client training."""

    def __init__(self, K):
        self.clients = list(range(K))

    def init(self, key):
        return {"w": jnp.zeros((2,))}

    def loss(self, params, batch):
        return jnp.sum(params["w"]) * 0.0 + jnp.sum(batch) * 0.0

    def client_batch(self, ci, round_rng, batch_size, num_batches):
        return jnp.zeros((num_batches, 1))

    def accuracy(self, params):
        return 0.0

    def val_loss(self, params):
        return 0.0


def _seed_window_loop(C, num_windows, decide, *, s_max=8):
    """The seed engine's host window loop (protocol only): per-satellite
    numpy arrays, a device SatState rebuilt for the scheduler EVERY window
    (the PR-2 `fl/engine.py` behavior the device-resident engine retired).
    Returns the final protocol state and counters for the parity check."""
    K = C.shape[1]
    version = np.zeros(K, np.int64)
    pending = np.zeros(K, np.int64)
    buffered = np.full(K, -1, np.int64)
    ig = total = idle = n_agg = 0
    hist = np.zeros(s_max + 1, np.int64)
    for i in range(num_windows):
        conn = C[i]
        total += int(conn.sum())
        has_pending = conn & (pending >= 0)
        idle += int((conn & ~has_pending & (version == ig)).sum())
        buffered[has_pending] = pending[has_pending]
        pending[has_pending] = -1
        n_buf = int((buffered >= 0).sum())
        state = SS.SatState(jnp.asarray(version, jnp.int32),
                            jnp.asarray(pending, jnp.int32),
                            jnp.asarray(buffered, jnp.int32))
        if decide(i, n_buf, state, ig) and n_buf > 0:
            ks = np.flatnonzero(buffered >= 0)
            np.add.at(hist, np.clip(ig - buffered[ks], 0, s_max), 1)
            n_agg += len(ks)
            ig += 1
            buffered[:] = -1
        behind = conn & (version < ig)
        version[behind] = ig
        pending[behind] = ig
    return {"version": version, "pending": pending, "ig": ig,
            "total": total, "idle": idle, "n_agg": n_agg, "hist": hist}


@section("window_loop",
         parity=lambda r: all(c["state_and_counters_identical"]
                              for c in r["per_K"].values()))
def bench_window_loop(smoke: bool) -> dict:
    Ks = [16] if smoke else [34, 191, 1000]
    W = 64 if smoke else 2048
    Wp = 48 if smoke else 256         # parity run (with aggregations)
    out = {"windows": W, "per_K": {}}
    for K in Ks:
        rng = np.random.default_rng(0)
        C = rng.random((W, K)) < 0.08
        adapter = _NullAdapter(K)

        # throughput: no aggregations => the loop is pure protocol
        M_never = K + 1
        cfg = EngineConfig(eval_every=W, max_windows=W)

        def run_device():
            eng = SimulationEngine(C, adapter,
                                   make_scheduler("fedbuff", M=M_never),
                                   cfg)
            t0 = time.perf_counter()
            eng.run()
            return time.perf_counter() - t0, eng

        def run_seed():
            t0 = time.perf_counter()
            fin = _seed_window_loop(C, W,
                                    lambda i, nb, st, ig: nb >= M_never)
            return time.perf_counter() - t0, fin

        t_dev_cold, eng = run_device()
        assert eng._fast_ok
        t_dev = min(run_device()[0] for _ in range(3))
        t_seed = min(run_seed()[0] for _ in range(3))

        # parity: aggregation-bearing schedule, every protocol counter and
        # the final state must match the seed loop exactly
        M = max(2, K // 8)
        Cp = np.random.default_rng(1).random((Wp, K)) < 0.08
        fin = _seed_window_loop(Cp, Wp, lambda i, nb, st, ig: nb >= M)
        peng = SimulationEngine(Cp, adapter,
                                make_scheduler("fedbuff", M=M),
                                EngineConfig(eval_every=Wp, max_windows=Wp))
        pres = peng.run()
        parity = (
            np.array_equal(peng.version, fin["version"])
            and np.array_equal(peng.pending, fin["pending"])
            and peng.ig == fin["ig"]
            and pres.total_connections == fin["total"]
            and pres.idle_connections == fin["idle"]
            and pres.num_aggregated_gradients == fin["n_agg"]
            and pres.staleness_hist.tolist() == fin["hist"].tolist())

        out["per_K"][str(K)] = {
            "t_seed_loop_s": t_seed,
            "t_device_loop_s": t_dev,
            "t_device_loop_cold_s": t_dev_cold,
            "windows_per_s_seed": W / t_seed,
            "windows_per_s_device": W / t_dev,
            "speedup": t_seed / t_dev,
            "state_and_counters_identical": bool(parity),
        }
        print(f"window_loop K={K}: seed {W / t_seed:.0f} win/s, device "
              f"{W / t_dev:.0f} win/s ({t_seed / t_dev:.1f}x), parity="
              f"{bool(parity)}", flush=True)
    return out


# ---------------------------------------------------------------------------
# 4. utility sampler


@section("utility_sampler",
         parity=lambda r: r["features_identical"] and r["targets_close"])
def bench_utility_sampler(smoke: bool) -> dict:
    from repro.core.utility import generate_utility_samples
    from repro.fl.client import (make_batched_client_update,
                                 make_client_update)
    from repro.fl.fedspace_setup import pretrain_trajectory
    num_train = 400 if smoke else 2000
    K = 12 if smoke else 40
    n_samples = 12 if smoke else 150
    cps = 8 if smoke else 32
    local_steps = 2 if smoke else 4
    data = SyntheticFmow(FmowSpec(num_train=num_train, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(
        iid_partition(num_train, K, 0)), hidden=48)
    traj = pretrain_trajectory(adapter, rounds=8, clients_per_round=8,
                               local_steps=local_steps, client_lr=0.3,
                               seed=0)
    cu = make_client_update(adapter, local_steps=local_steps, lr=0.3)

    def upd_fn(base, ci, r):
        return cu(base, ci, round_rng=int(r))

    common = dict(num_clients=K, n_samples=n_samples, s_max=8,
                  clients_per_sample=cps, seed=3)
    val_batch = adapter.eval_batch()
    vec_kw = dict(
        batch_fn=lambda ci, r: adapter.client_batch(ci, int(r), 32,
                                                    local_steps),
        batched_update_fn=make_batched_client_update(
            adapter, local_steps=local_steps, lr=0.3),
        batched_loss_fn=jax.jit(jax.vmap(
            lambda p: adapter.loss(p, val_batch))))

    def run(kw):
        t0 = time.perf_counter()
        X, y = generate_utility_samples(
            jax.random.PRNGKey(0), traj, upd_fn,
            lambda p: adapter.val_loss(p), **common, **kw)
        return time.perf_counter() - t0, X, y

    t_vec_cold, Xv, yv = run(vec_kw)
    t_vec = min(run(vec_kw)[0] for _ in range(2))
    t_loop, Xl, yl = run({})
    t_loop = min(t_loop, run({})[0])
    print(f"utility_sampler: loop {t_loop:.3f}s, vectorized {t_vec:.3f}s "
          f"({t_loop / t_vec:.1f}x), features_identical="
          f"{bool(np.array_equal(Xl, Xv))}, targets_close="
          f"{bool(np.allclose(yl, yv, atol=1e-5))}", flush=True)
    return {
        "n_samples": n_samples, "clients_per_sample": cps,
        "num_clients": K, "local_steps": local_steps,
        "t_loop_s": t_loop,
        "t_vectorized_s": t_vec,
        "t_vectorized_cold_s": t_vec_cold,
        "speedup": t_loop / t_vec,
        "features_identical": bool(np.array_equal(Xl, Xv)),
        "targets_max_abs_diff": float(np.abs(yl - yv).max()),
        "targets_close": bool(np.allclose(yl, yv, atol=1e-5)),
    }


# ---------------------------------------------------------------------------
# 6. link budget: trivial-budget parity gate + the downlink-capacity study


def _protocol_run(C, budget, *, M, windows, eval_every=None):
    """One protocol-isolated engine run (NullAdapter, fedbuff M); returns
    (engine, result, wall seconds)."""
    K = C.shape[1]
    eng = SimulationEngine(
        C, _NullAdapter(K), make_scheduler("fedbuff", M=M),
        EngineConfig(eval_every=eval_every or windows, max_windows=windows),
        link_budget=budget)
    t0 = time.perf_counter()
    res = eng.run()
    return eng, res, time.perf_counter() - t0


def _capacity_cell(spec, *, days, windows, link_kw, M):
    """Run one ground-network cell of the capacity study and digest the
    idle/blocked/staleness statistics."""
    from repro.core.connectivity import link_budget
    budget = link_budget(spec, days=days, **link_kw)
    eng, res, t = _protocol_run(budget.served, budget, M=M,
                                windows=windows)
    hist = res.staleness_hist
    n_agg = int(hist.sum())
    return {
        "stations": len(spec.ground_stations),
        "visible_contacts": int(budget.visible[:windows].sum()),
        "served_contacts": int(budget.served[:windows].sum()),
        "blocked_fraction": float(
            (budget.visible[:windows] & ~budget.served[:windows]).sum()
            / max(budget.visible[:windows].sum(), 1)),
        "idle_fraction": res.idle_connections
        / max(res.total_connections, 1),
        "global_updates": res.num_global_updates,
        "aggregated_gradients": res.num_aggregated_gradients,
        "mean_staleness": float((hist * np.arange(len(hist))).sum()
                                / max(n_agg, 1)),
        "t_run_s": t,
    }


@section("link_budget",
         parity=lambda r: r["trivial_trajectory_identical"]
         and r["trivial_schedule_identical"] and r["capacity_stats_differ"])
def bench_link_budget(smoke: bool) -> dict:
    """(a) Parity gate: the trivial budget — unlimited station capacity,
    zero-latency transfers — must reproduce the geometry-only engine
    trajectory and the geometry-only search schedule bit-for-bit (the
    contract every link-budget code path is gated on). (b) Capacity
    study: identical constellation and protocol over dense12 vs sparse1
    ground networks under finite rates and per-station capacity — the
    idle/blocked/staleness statistics must differ measurably, which is
    exactly what the geometry-only contact model could not show."""
    from repro.core.connectivity import (ConstellationSpec, link_budget,
                                         resolve_spec, transfer_windows)
    K = 16 if smoke else 191
    days = 0.25 if smoke else 1.0
    windows = int(days * 96)
    # smoke: a wide 10-deg visibility cone + capacity 1, so even 16
    # satellites over a quarter day produce real shared-station contention
    base = ConstellationSpec() if not smoke \
        else ConstellationSpec(num_satellites=K, min_elevation_deg=10.0)
    capacity = 2 if not smoke else 1
    M = max(2, K // 8)

    # (a) trivial-budget parity: same trajectory, bit for bit
    trivial = link_budget(base, days=days)    # all sentinels: gates nothing
    C = trivial.visible
    e0, r0, t_geom = _protocol_run(C, None, M=M, windows=windows,
                                   eval_every=windows // 2)
    e1, r1, t_gated = _protocol_run(C, trivial, M=M, windows=windows,
                                    eval_every=windows // 2)
    traj_ok = (
        np.array_equal(e0.version, e1.version)
        and np.array_equal(e0.pending, e1.pending)
        and np.array_equal(e0.buffered_base, e1.buffered_base)
        and e0.ig == e1.ig
        and r0.total_connections == r1.total_connections
        and r0.idle_connections == r1.idle_connections
        and r0.staleness_hist.tolist() == r1.staleness_hist.tolist())

    rf = _fit_search_regressor()
    I0 = 8 if smoke else 24
    Cw = C[:I0]
    R = 64 if smoke else 5000
    sched0 = fedspace_search(np.random.default_rng(7), Cw,
                             SS.bootstrap_state(K), 0, rf, 1.0,
                             num_candidates=R, s_max=8)
    gate = SS.LinkGate((np.ones_like(Cw, np.int32) * Cw), 0, 0)
    sched1 = fedspace_search(np.random.default_rng(7), Cw,
                             SS.bootstrap_state(K, progress=True), 0, rf,
                             1.0, num_candidates=R, s_max=8, link=gate)
    sched_ok = bool(np.array_equal(sched0, sched1))

    # (b) capacity study: dense12 vs sparse1, finite rates + station caps
    link_kw = dict(uplink_mbps=20.0, downlink_mbps=100.0, model_mb=600.0,
                   gs_capacity=capacity)
    cells = {g: _capacity_cell(resolve_spec(base, g, None), days=days,
                               windows=windows, link_kw=link_kw, M=M)
             for g in ("dense12", "sparse1")}
    d12, sp1 = cells["dense12"], cells["sparse1"]
    stats_differ = bool(
        sp1["blocked_fraction"] > d12["blocked_fraction"]
        and sp1["aggregated_gradients"] < d12["aggregated_gradients"])

    print(f"link_budget: trivial gate {t_gated:.3f}s vs geometry "
          f"{t_geom:.3f}s, trajectory_identical={traj_ok}, "
          f"schedule_identical={sched_ok}", flush=True)
    for g, c in cells.items():
        print(f"link_budget {g}: blocked {c['blocked_fraction']:.2f}, "
              f"idle {c['idle_fraction']:.2f}, "
              f"agg_gradients {c['aggregated_gradients']}, "
              f"mean_staleness {c['mean_staleness']:.2f}", flush=True)
    return {
        "K": K, "windows": windows,
        "need_up": transfer_windows(link_kw["uplink_mbps"],
                                    link_kw["model_mb"]),
        "need_dn": transfer_windows(link_kw["downlink_mbps"],
                                    link_kw["model_mb"]),
        "gs_capacity": link_kw["gs_capacity"],
        "t_geometry_run_s": t_geom,
        "t_trivial_gated_run_s": t_gated,
        "trivial_trajectory_identical": bool(traj_ok),
        "trivial_schedule_identical": sched_ok,
        "capacity_cells": cells,
        "capacity_stats_differ": stats_differ,
    }


# ---------------------------------------------------------------------------
# 7. inter-satellite links: identity-topology parity gate + idle-time study


def _isl_run(C, scheduler, *, windows, isl=None, budget=None, fast=True,
             faults=None):
    """One protocol-isolated engine run under an optional ISL runtime and
    fault trace; returns (engine, result, wall seconds)."""
    K = C.shape[1]
    eng = SimulationEngine(
        C, _NullAdapter(K), scheduler,
        EngineConfig(eval_every=windows, max_windows=windows,
                     fast_loop=fast),
        link_budget=budget, isl=isl, faults=faults)
    t0 = time.perf_counter()
    res = eng.run()
    return eng, res, time.perf_counter() - t0


def _same_trajectory(a, b, ra, rb):
    return (np.array_equal(a.version, b.version)
            and np.array_equal(a.pending, b.pending)
            and np.array_equal(a.buffered_base, b.buffered_base)
            and a.ig == b.ig
            and ra.idle_connections == rb.idle_connections
            and ra.total_connections == rb.total_connections
            and ra.staleness_hist.tolist() == rb.staleness_hist.tolist())


@section("isl",
         parity=lambda r: r["identity_trajectory_identical"]
         and r.get("idle_reduced", True))
def bench_isl(smoke: bool) -> dict:
    """(a) Parity gate: the degenerate identity topology (every satellite
    its own singleton plane, all links self-loops) run through the sink
    scheduler must reproduce the ground-only fedbuff trajectory
    bit-for-bit under BOTH engine strategies — the contract that `isl`
    only changes what the topology says it changes. (b) Idle-time study
    (full runs only): the sparse-ground starlink preset under a finite
    link budget, FedSpace / fedbuff / intra-plane sinks / ISL gossip —
    the regime arXiv 2302.13447 targets, where relaying whole planes
    through their best-placed contact must cut the eq.-10 idle share
    below the ground-only schedulers'."""
    from repro.core import isl as ISL
    from repro.core.connectivity import (connectivity_sets,
                                         constellation_preset, link_budget)
    K = 16 if smoke else 40
    windows = 48 if smoke else 96
    M = max(2, K // 8)

    # (a) identity-topology parity, both strategies
    if smoke:
        C = np.random.default_rng(0).random((windows, K)) < 0.08
    else:
        C = connectivity_sets(constellation_preset("starlink40"), days=1.0)
    ident = ISL.ISL(topology=ISL.identity_topology(K), relay_windows=0,
                    epoch=24)
    e0, r0, t_ground = _isl_run(C, make_scheduler("fedbuff", M=M),
                                windows=windows)
    parity = True
    t_fast = t_host = 0.0
    for fast in (True, False):
        e1, r1, t1 = _isl_run(C, make_scheduler("intra_plane", M=M),
                              windows=windows, isl=ident, fast=fast)
        parity = parity and _same_trajectory(e0, e1, r0, r1)
        if fast:
            t_fast = t1
        else:
            t_host = t1
    print(f"isl: identity-parity ground {t_ground:.3f}s, sink fast "
          f"{t_fast:.3f}s, sink host {t_host:.3f}s, "
          f"trajectory_identical={bool(parity)}", flush=True)
    out = {
        "K": K, "windows": windows, "M": M,
        "t_ground_run_s": t_ground,
        "t_sink_fast_s": t_fast,
        "t_sink_host_s": t_host,
        "identity_trajectory_identical": bool(parity),
    }
    if smoke:
        return out

    # (b) idle-time study: starlink40 over the single Svalbard station
    # with finite rates and station capacity; the 53-deg shells never see
    # the station, so ground-only policies leave the polar shell carrying
    # everything while sink relaying pulls whole planes into each pass.
    # FedSpace plans at the paper's schedule density (n in [4, 8] per
    # I0 = 24); the sink threshold matches fedbuff's M so the comparison
    # isolates the relay mechanism, not the aggregation cadence.
    spec = constellation_preset("starlink40", ground="sparse1")
    days = 2.0
    study_windows = int(days * 96)
    budget = link_budget(spec, days=days, uplink_mbps=20.0,
                         downlink_mbps=100.0, model_mb=600.0,
                         gs_capacity=2)
    runtime = ISL.build_isl(spec, ISL.ISLConfig(isl_mbps=100.0,
                                                model_mb=600.0, epoch=24))
    reach = ISL.reachable_count(runtime.topology,
                                budget.served[:study_windows])
    M_study = max(2, reach // 4)
    rf = _fit_search_regressor()
    scheds = {
        "fedspace": make_scheduler("fedspace", regressor=rf, I0=24,
                                   n_min=4, n_max=8, num_candidates=512,
                                   seed=0),
        "fedbuff": make_scheduler("fedbuff", M=M_study),
        "intra_plane": make_scheduler("intra_plane", M=M_study),
        "isl_async": make_scheduler("isl_async"),
    }
    cells = {}
    for name, sched in scheds.items():
        eng, res, t = _isl_run(budget.served, sched, windows=study_windows,
                               isl=runtime, budget=budget)
        cells[name] = {
            "idle_fraction": res.idle_connections
            / max(res.total_connections, 1),
            "idle_connections": res.idle_connections,
            "total_connections": res.total_connections,
            "global_updates": res.num_global_updates,
            "aggregated_gradients": res.num_aggregated_gradients,
            "t_run_s": t,
        }
        print(f"isl {name}: idle {cells[name]['idle_fraction']:.2f} "
              f"({res.idle_connections}/{res.total_connections}), "
              f"updates {res.num_global_updates}, grads "
              f"{res.num_aggregated_gradients}", flush=True)
    out.update({
        "study_preset": "starlink40", "study_ground": "sparse1",
        "study_windows": study_windows, "study_M": M_study,
        "reachable_satellites": reach,
        "study_cells": cells,
        "idle_reduced": bool(cells["intra_plane"]["idle_fraction"]
                             < cells["fedspace"]["idle_fraction"]),
    })
    return out


# ---------------------------------------------------------------------------
# 8. fault injection: all-alive parity gate + the churn/blackout study


@section("faults",
         parity=lambda r: r["all_alive_trajectory_identical"]
         and r.get("degradation_observed", True))
def bench_faults(smoke: bool) -> dict:
    """(a) Parity gate: an all-alive fault trace — no deorbits, every
    station up, unit weather — must reproduce the ``faults=None``
    trajectory bit-for-bit under BOTH engine strategies, on the
    geometry-only path and the link-budget path (the contract that fault
    injection is a pure mask over the clean artifacts, and that the
    inactive masks add nothing to the compiled programs). (b) Degradation
    study (full runs only): sync / fedbuff / fedspace / intra-plane sinks
    on starlink40 over the dense12 ground network under *blind* faults —
    escalating satellite churn, a total ground-network blackout, and
    weather-degraded links — reporting the idle/staleness/aggregated-
    gradient curves each scheduler traces as the planned and executed
    worlds diverge."""
    from repro.core import isl as ISL
    from repro.core.connectivity import (LinkBudget, constellation_preset,
                                         link_budget)
    from repro.core.faults import (FaultConfig, fault_trace, random_churn,
                                   station_blackout)

    # (a) all-alive parity, geometry and budget paths, both strategies
    Kp, Wp = 16, 64
    rng = np.random.default_rng(0)
    Cp = rng.random((Wp, Kp)) < 0.2
    grants = (rng.integers(1, 4, Cp.shape) * Cp).astype(np.int32)
    assign = np.where(Cp, rng.integers(0, 3, Cp.shape), -1).astype(np.int32)
    bp = LinkBudget(visible=Cp, served=Cp, assign=assign, grants=grants,
                    need_up=2, need_dn=1)
    alive_trace = fault_trace(FaultConfig(), Wp, K=Kp, num_stations=3)
    M = max(2, Kp // 8)
    parity = True
    t_none = t_alive = 0.0
    for budget in (None, bp):
        e0, r0, t0 = _isl_run(Cp, make_scheduler("fedbuff", M=M),
                              windows=Wp, budget=budget)
        t_none += t0
        for fast in (True, False):
            e1, r1, t1 = _isl_run(Cp, make_scheduler("fedbuff", M=M),
                                  windows=Wp, budget=budget, fast=fast,
                                  faults=alive_trace)
            parity = parity and _same_trajectory(e0, e1, r0, r1)
            if budget is not None:
                parity = parity and np.array_equal(e0.transfer_progress,
                                                   e1.transfer_progress)
            if fast:
                t_alive += t1
    print(f"faults: all-alive gate none {t_none:.3f}s, traced "
          f"{t_alive:.3f}s, trajectory_identical={bool(parity)}",
          flush=True)
    out = {
        "gate_K": Kp, "gate_windows": Wp,
        "t_none_runs_s": t_none,
        "t_all_alive_runs_s": t_alive,
        "all_alive_trajectory_identical": bool(parity),
    }
    if smoke:
        return out

    # (b) degradation study: starlink40 over dense12 under blind faults.
    # The schedulers plan on the clean connectivity the search was promised
    # (§3.1's determinism premise) while the engine executes the faulted
    # world — the curves measure how gracefully each policy degrades when
    # that premise breaks. Churn fractions share one seed so the fault
    # sets nest and the curves are comparable.
    spec = constellation_preset("starlink40")
    days = 2.0
    W = int(days * 96)
    G = len(spec.ground_stations)
    K = spec.num_satellites
    budget = link_budget(spec, days=days, uplink_mbps=20.0,
                         downlink_mbps=100.0, model_mb=600.0,
                         gs_capacity=2)
    runtime = ISL.build_isl(spec, ISL.ISLConfig(isl_mbps=100.0,
                                                model_mb=600.0, epoch=24))
    reach = ISL.reachable_count(runtime.topology, budget.served[:W])
    M_study = max(2, reach // 4)
    rf = _fit_search_regressor()
    sched_fns = {
        "sync": lambda: make_scheduler("sync"),
        "fedbuff": lambda: make_scheduler("fedbuff", M=M_study),
        "fedspace": lambda: make_scheduler(
            "fedspace", regressor=rf, I0=24, n_min=4, n_max=8,
            num_candidates=512, seed=0),
        "intra_plane": lambda: make_scheduler("intra_plane", M=M_study),
    }
    scenarios = {
        "clean": None,
        "churn20": FaultConfig(deorbit=random_churn(K, W, 0.20, seed=0)),
        "churn40": FaultConfig(deorbit=random_churn(K, W, 0.40, seed=0)),
        "blackout": FaultConfig(
            outages=station_blackout(G, W // 3, 2 * W // 3)),
        "weather": FaultConfig(rate_scale_min=0.25, rate_scale_max=1.0,
                               seed=1),
    }
    traces = {n: None if c is None
              else fault_trace(c, W, K=K, num_stations=G)
              for n, c in scenarios.items()}
    cells = {}
    for sname, make in sched_fns.items():
        cells[sname] = {}
        for scen, trace in traces.items():
            eng, res, t = _isl_run(budget.served, make(), windows=W,
                                   isl=runtime, budget=budget,
                                   faults=trace)
            hist = res.staleness_hist
            n_agg = int(hist.sum())
            cells[sname][scen] = {
                "idle_fraction": res.idle_connections
                / max(res.total_connections, 1),
                "total_connections": res.total_connections,
                "global_updates": res.num_global_updates,
                "aggregated_gradients": res.num_aggregated_gradients,
                "mean_staleness": float(
                    (hist * np.arange(len(hist))).sum() / max(n_agg, 1)),
                "t_run_s": t,
            }
        curve = " ".join(
            f"{scen}={c['aggregated_gradients']}"
            for scen, c in cells[sname].items())
        print(f"faults {sname}: agg_gradients {curve}", flush=True)

    def agg(s, scen):
        return cells[s][scen]["aggregated_gradients"]

    degradation = bool(all(
        agg(s, "churn40") < agg(s, "clean")
        for s in ("fedbuff", "fedspace")))
    out.update({
        "study_preset": "starlink40", "study_ground": "dense12",
        "study_windows": W, "study_M": M_study,
        "churn_fractions": [0.0, 0.2, 0.4],
        "blackout_windows": [W // 3, 2 * W // 3],
        "study_cells": cells,
        "degradation_observed": degradation,
    })
    return out


# ---------------------------------------------------------------------------
# 9. sweep scaling: batched whole-experiment dispatch + the sharded-K gate


_MESH_GATE_SCRIPT = r"""
import os, sys, time, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, {src!r})
import numpy as np
import jax.numpy as jnp
from repro.core import mesh as MM
from repro.core.scheduler import make_scheduler
from repro.fl.engine import EngineConfig, SimulationEngine

K, W, M = {K}, {W}, {M}

class _NullAdapter:
    def __init__(self, K): self.clients = list(range(K))
    def init(self, key): return {{"w": jnp.zeros((2,))}}
    def loss(self, params, batch):
        return jnp.sum(params["w"]) * 0.0 + jnp.sum(batch) * 0.0
    def client_batch(self, ci, round_rng, batch_size, num_batches):
        return jnp.zeros((num_batches, 1))
    def accuracy(self, params): return 0.0
    def val_loss(self, params): return 0.0

C = np.random.default_rng(0).random((W, K)) < 0.08

def run(mesh):
    eng = SimulationEngine(C, _NullAdapter(K),
                           make_scheduler("fedbuff", M=M),
                           EngineConfig(eval_every=W, max_windows=W),
                           mesh=mesh)
    t0 = time.perf_counter()
    res = eng.run()
    return eng, res, time.perf_counter() - t0

mesh = MM.sim_mesh()
e0, r0, _ = run(None)
t_single = min(run(None)[2] for _ in range(2))
e1, r1, _ = run(mesh)
t_mesh = min(run(mesh)[2] for _ in range(2))
identical = (np.array_equal(e0.version, e1.version)
             and np.array_equal(e0.pending, e1.pending)
             and np.array_equal(e0.buffered_base, e1.buffered_base)
             and e0.ig == e1.ig
             and r0.idle_connections == r1.idle_connections
             and r0.staleness_hist.tolist() == r1.staleness_hist.tolist())
print("MESH_GATE " + json.dumps({{
    "K": K, "windows": W, "devices": MM.mesh_size(mesh),
    "t_single_device_s": t_single, "t_mesh_s": t_mesh,
    "trajectory_identical": bool(identical)}}))
"""


def _mesh_gate(*, K, W, M):
    """Run the sharded-K parity gate on a forced 8-virtual-device CPU mesh
    in a fresh subprocess (the device count locks at first jax init, so
    the bench process itself cannot host it)."""
    import subprocess
    import sys
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    script = _MESH_GATE_SCRIPT.format(
        src=os.path.join(_ROOT, "src"), K=K, W=W, M=M)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=1200,
                       cwd=_ROOT, env=env)
    if r.returncode != 0:
        raise SystemExit(f"mesh gate subprocess failed:\n{r.stderr[-2000:]}")
    line = [l for l in r.stdout.splitlines()
            if l.startswith("MESH_GATE ")][-1]
    return json.loads(line[len("MESH_GATE "):])


@section("sweep_scaling",
         parity=lambda r: r["per_variant_identical"]
         and r["mesh_gate"]["trajectory_identical"])
def bench_sweep_scaling(smoke: bool) -> dict:
    """(a) Batched dispatch: a fedbuff-M x churn-fraction x seed grid of
    whole experiment variants over one world, run once as V sequential
    engine runs and once as a single `jit(vmap)` sweep dispatch
    (`repro.fl.sweep.sweep_engines`), parity-gated on every variant's
    protocol counters and final state being bit-identical. (b) Sharded-K
    gate: a fedbuff run at starlink1000 scale under `mesh=sim_mesh()` on
    a forced 8-virtual-device CPU mesh must be trajectory-bit-identical
    to the single-device run (subprocess, since the device count locks at
    first jax init)."""
    from repro.core.faults import FaultConfig, fault_trace, random_churn
    from repro.fl import sweep as SW
    if smoke:
        K, W = 12, 48
        Ms, fracs, seeds = (2, 4), (0.1, 0.2), (0, 1)        # V = 8
    else:
        K, W = 40, 192
        Ms, fracs, seeds = (2, 3, 4, 6), (0.1, 0.2, 0.3, 0.4), (0, 1)
    C = np.random.default_rng(0).random((W, K)) < 0.08
    adapter = _NullAdapter(K)
    grid = [(M, f, s) for M in Ms for f in fracs for s in seeds]
    traces = {(f, s): fault_trace(
        FaultConfig(deorbit=random_churn(K, W, f, seed=s)), W, K=K)
        for _, f, s in grid}

    def build():
        return [SimulationEngine(
            C, adapter, make_scheduler("fedbuff", M=M),
            EngineConfig(eval_every=W, max_windows=W),
            faults=traces[(f, s)]) for M, f, s in grid]

    # every variant shares the fedbuff indicator and column layout, so the
    # whole grid is ONE vmapped dispatch — count the groups to prove it
    groups = {SW._variant_columns(e)[0] for e in build()}

    def run_sequential():
        t0 = time.perf_counter()
        out = [(e, e.run()) for e in build()]
        return time.perf_counter() - t0, out

    def run_batched():
        engines = build()
        t0 = time.perf_counter()
        outs = SW.sweep_engines(engines)
        return time.perf_counter() - t0, outs

    _, seq = run_sequential()               # cold: pays the jit compiles
    t_seq = min(run_sequential()[0] for _ in range(2))
    t_swp_cold, outs = run_batched()
    t_swp = min(run_batched()[0] for _ in range(2))

    identical = all(
        np.array_equal(e.version, o.version)
        and np.array_equal(e.pending, o.pending)
        and np.array_equal(e.buffered_base, o.buffered)
        and e.ig == o.ig
        and r.staleness_hist.tolist() == o.result.staleness_hist.tolist()
        and r.idle_connections == o.result.idle_connections
        and r.total_connections == o.result.total_connections
        and r.num_global_updates == o.result.num_global_updates
        and r.num_aggregated_gradients
        == o.result.num_aggregated_gradients
        for (e, r), o in zip(seq, outs))

    print(f"sweep_scaling: {len(grid)} variants sequential {t_seq:.3f}s, "
          f"batched {t_swp:.3f}s ({t_seq / t_swp:.1f}x), "
          f"dispatch_groups={len(groups)}, per_variant_identical="
          f"{bool(identical)}", flush=True)

    gate = _mesh_gate(K=100 if smoke else 1000, W=48 if smoke else 96,
                      M=12)
    print(f"sweep_scaling mesh gate: K={gate['K']} on {gate['devices']} "
          f"devices, single {gate['t_single_device_s']:.3f}s, mesh "
          f"{gate['t_mesh_s']:.3f}s, trajectory_identical="
          f"{gate['trajectory_identical']}", flush=True)
    return {
        "num_variants": len(grid), "K": K, "windows": W,
        "dispatch_groups": len(groups),
        "t_sequential_s": t_seq,
        "t_batched_s": t_swp,
        "t_batched_cold_s": t_swp_cold,
        "speedup": t_seq / t_swp,
        "per_variant_identical": bool(identical),
        "mesh_gate": gate,
    }


# ---------------------------------------------------------------------------
# 10. real payloads: compression-off parity gate + bytes-on-the-wire study


def _payload_exp(*, preset="", num_satellites=10, ground="", days,
                 adapter_kind="transformer", adapter_params=None,
                 scheduler="fedbuff", sched_params=None, model_mb=300.0,
                 topk=0.0, int8=False, train_topk=None, fast=True,
                 windows, eval_every, num_train=240, num_val=80,
                 local_steps=2):
    from repro.fl.api import (AdapterConfig, ConstellationConfig,
                              DatasetConfig, FLExperiment, LinkConfig,
                              SchedulerConfig)
    return FLExperiment(
        constellation=ConstellationConfig(num_satellites=num_satellites,
                                          days=days, preset=preset,
                                          ground=ground),
        dataset=DatasetConfig(num_train=num_train, num_val=num_val),
        adapter=AdapterConfig(kind=adapter_kind,
                              params=dict(adapter_params or {})),
        scheduler=SchedulerConfig(kind=scheduler,
                                  params=dict(sched_params or {})),
        train=EngineConfig(eval_every=eval_every, max_windows=windows,
                           local_steps=local_steps, fast_loop=fast,
                           uplink_topk=train_topk),
        link=LinkConfig(uplink_topk=topk, uplink_int8=int8,
                        uplink_mbps=20.0, downlink_mbps=100.0,
                        model_mb=model_mb, gs_capacity=1),
    )


def _payload_run(exp):
    from repro.fl.api import Federation
    fed = Federation.from_experiment(exp)
    eng = fed.engine()
    t0 = time.perf_counter()
    res = eng.run()
    return fed, eng, res, time.perf_counter() - t0


@section("payloads",
         parity=lambda r: r["compression_off_trajectory_identical"]
         and r.get("need_up_reduced", True)
         and r.get("agg_gradients_shift", True))
def bench_payloads(smoke: bool) -> dict:
    """(a) Parity gate: a transformer federation — Pallas-dispatch forward,
    real client batches, a finite link budget — run with `uplink_topk`
    unset (None), an explicit 0.0, and under both engine strategies must
    produce one bit-identical trajectory AND bit-identical final model
    parameters: compression off is the absence of the feature, not a
    cheap approximation of it. (b) Bytes-on-the-wire study (full runs
    only): starlink40 over the single sparse1 station under a finite
    budget, sweeping model family (mlp vs transformer, with their wire
    sizes) x compression (off / top-k 0.25 / dense int8) x scheduler
    (fedbuff / async) — gated on compression measurably cutting
    `need_up` and shifting the aggregated-gradient counts, the coupling
    a bytes-blind contact model cannot express."""
    from repro.fl.compression import uplink_bytes_ratio

    # (a) compression-off parity, both sentinels x both strategies
    gate_kw = dict(num_satellites=10, days=0.25, windows=24, eval_every=12)
    gp = {"d_model": 16, "num_layers": 1, "num_heads": 2,
          "num_kv_heads": 1, "d_ff": 32}
    _, e0, r0, t_ref = _payload_run(_payload_exp(
        adapter_params=gp, train_topk=None, fast=True, **gate_kw))
    parity = True
    t_variants = 0.0
    for train_topk, fast in ((0.0, True), (None, False), (0.0, False)):
        _, e1, r1, t1 = _payload_run(_payload_exp(
            adapter_params=gp, train_topk=train_topk, fast=fast, **gate_kw))
        t_variants += t1
        parity = (parity and _same_trajectory(e0, e1, r0, r1)
                  and r0.accuracy == r1.accuracy
                  and all(np.array_equal(np.asarray(a), np.asarray(b))
                          for a, b in zip(jax.tree.leaves(e0.params),
                                          jax.tree.leaves(e1.params))))
    print(f"payloads: compression-off gate ref {t_ref:.3f}s, variants "
          f"{t_variants:.3f}s, trajectory_identical={bool(parity)}",
          flush=True)
    out = {
        "gate_K": 10, "gate_windows": 24,
        "t_gate_ref_s": t_ref,
        "t_gate_variants_s": t_variants,
        "compression_off_trajectory_identical": bool(parity),
    }
    if smoke:
        return out

    # (b) the study: one constellation/station world, model x compression
    # x scheduler. Wire sizes are per family (the transformer pytree is
    # the heavy payload); compression rescales the effective upload bytes
    # through `uplink_bytes_ratio`, so `need_up` — and with it how often
    # uploads complete inside a pass — moves with the ratio.
    models = {
        "mlp": ({"hidden": 64}, 300.0),
        "transformer": ({}, 600.0),          # default decoder stack
    }
    compression = {
        "off": dict(topk=0.0, int8=False),
        "topk25": dict(topk=0.25, int8=False),
        "int8": dict(topk=0.0, int8=True),
    }
    scheds = {
        "fedbuff": ("fedbuff", {"M": 2}),
        "async": ("async", {}),
    }
    days, windows = 2.0, 192
    cells = {}
    for mname, (mp, mb) in models.items():
        for cname, ckw in compression.items():
            for sname, (skind, skw) in scheds.items():
                fed, eng, res, t = _payload_run(_payload_exp(
                    preset="starlink40", ground="sparse1", days=days,
                    windows=windows, eval_every=windows,
                    adapter_kind=mname, adapter_params=mp, model_mb=mb,
                    scheduler=skind, sched_params=skw,
                    num_train=600, num_val=200, **ckw))
                b = fed.link_budget
                cells[f"{mname}/{cname}/{sname}"] = {
                    "model_mb": mb,
                    "bytes_ratio": uplink_bytes_ratio(
                        ckw["topk"], int8=ckw["int8"]),
                    "need_up": b.need_up, "need_dn": b.need_dn,
                    "global_updates": res.num_global_updates,
                    "aggregated_gradients": res.num_aggregated_gradients,
                    "idle_fraction": res.idle_connections
                    / max(res.total_connections, 1),
                    "final_accuracy": res.accuracy[-1],
                    "t_run_s": t,
                }
                c = cells[f"{mname}/{cname}/{sname}"]
                print(f"payloads {mname}/{cname}/{sname}: need_up "
                      f"{c['need_up']}, grads {c['aggregated_gradients']}, "
                      f"acc {c['final_accuracy']:.3f}", flush=True)
    need_up_reduced = all(
        cells[f"{m}/{c}/{s}"]["need_up"] < cells[f"{m}/off/{s}"]["need_up"]
        for m in models for c in ("topk25", "int8") for s in scheds)
    agg_shift = any(
        cells[f"{m}/{c}/{s}"]["aggregated_gradients"]
        != cells[f"{m}/off/{s}"]["aggregated_gradients"]
        for m in models for c in ("topk25", "int8") for s in scheds)
    out.update({
        "study_preset": "starlink40", "study_ground": "sparse1",
        "study_windows": windows,
        "study_cells": cells,
        "need_up_reduced": bool(need_up_reduced),
        "agg_gradients_shift": bool(agg_shift),
    })
    return out


# ---------------------------------------------------------------------------
# 11. incremental replan service: delta-vs-full parity gate + latency study


@section("replan",
         parity=lambda r: r["selection_identical"] and r["delta_steps"] >= 1)
def bench_replan(smoke: bool) -> dict:
    """Incremental replanning (`repro.fl.replan.ReplanService`): on each
    consecutive aggregation event the service reuses the cached rollout
    prefix over the overlapping horizon and simulates only the newly
    revealed window. Parity: every answered schedule must be bit-identical
    to a full `score_candidates` + `select_candidate` rescan of the
    service's own live pool from the caller's state, and at least one
    request must have taken the delta path. The study reports the warm
    delta answer latency against the full-rescan latency at the same
    shapes (the serving claim in docs/replanning.md), plus the deferred
    `maintain()` cost."""
    from repro.core.search import score_candidates, select_candidate
    from repro.fl.replan import ReplanService

    K = 16 if smoke else 1000         # starlink1000 scale
    R = 256 if smoke else 20000       # serving-scale candidate pool
    I0 = 8 if smoke else 24
    steps = 8
    s_max = 8
    rf = _fit_search_regressor(s_max=s_max)
    rng = np.random.default_rng(0)
    C = rng.random((I0 + steps, K)) < 0.15

    svc = ReplanService(rf, I0=I0, num_candidates=R, n_min=4, n_max=8,
                        s_max=s_max, seed=3,
                        min_pool=16 if smoke else 256)
    state = jax.tree.map(np.asarray, SS.bootstrap_state(K))
    ig = 0
    draw_rng = np.random.default_rng(7)

    identical = True
    t_delta, t_maintain, t_full = [], [], []
    for i in range(steps):
        Cw = C[i:i + I0]
        t0 = time.perf_counter()
        plan = svc.replan(i, Cw, state, ig, 1.0, rng=draw_rng)
        t_ans = time.perf_counter() - t0
        if svc.last_mode == "delta":
            t_delta.append(t_ans)
            t0 = time.perf_counter()
            svc.maintain()               # deferred advance, off the answer
            t_maintain.append(time.perf_counter() - t0)
        # the gate: full rescan of the live pool from the caller's state
        pool = svc.pool
        t0 = time.perf_counter()
        scores = score_candidates(pool, Cw, state, ig, rf, 1.0,
                                  s_max=s_max)
        w = select_candidate(pool, scores)
        t_full.append(time.perf_counter() - t0)
        identical = identical and bool(np.array_equal(plan, pool[w]))
        # realize the winning bit: the true state advances one window
        st, g, _ = SS.step(jax.tree.map(jnp.asarray, state),
                           jnp.int32(ig), jnp.asarray(C[i]),
                           jnp.asarray(bool(plan[0])), s_max=s_max,
                           collect="none")
        state = jax.tree.map(np.asarray, st)
        ig = int(g)

    # warm numbers: drop each path's first (compile-bearing) sample
    warm_delta_ms = (min(t_delta[1:] or t_delta) * 1e3
                     if t_delta else None)
    warm_full_ms = min(t_full[1:] or t_full) * 1e3
    out = {
        "K": K, "num_candidates": R, "I0": I0, "steps": steps,
        "delta_steps": len(t_delta),
        "full_steps": svc.stats["full"],
        "invalidated": dict(svc.stats["invalidated"]),
        "warm_delta_ms": warm_delta_ms,
        "warm_full_rescan_ms": warm_full_ms,
        "maintain_ms": (min(t_maintain[1:] or t_maintain) * 1e3
                        if t_maintain else None),
        "speedup_warm": (warm_full_ms / warm_delta_ms
                         if warm_delta_ms else None),
        "selection_identical": bool(identical),
    }
    print(f"replan: {out['delta_steps']}/{steps} delta, warm delta "
          f"{warm_delta_ms and round(warm_delta_ms, 1)}ms vs full rescan "
          f"{warm_full_ms:.1f}ms, maintain "
          f"{out['maintain_ms'] and round(out['maintain_ms'], 1)}ms, "
          f"selection_identical={bool(identical)}", flush=True)
    return out


# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI harness-rot check)")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_hotpaths.json, or BENCH_hotpaths.smoke.json "
                         "with --smoke)")
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of registered sections to "
                         "run (e.g. --sections faults,isl); other sections' "
                         "entries are preserved from the existing report")
    args = ap.parse_args()

    out_path = args.out or os.path.join(
        _ROOT, "BENCH_hotpaths.smoke.json" if args.smoke
        else "BENCH_hotpaths.json")

    selected = SECTIONS
    if args.sections:
        names = [n for n in args.sections.split(",") if n]
        unknown = [n for n in names if n not in SECTIONS]
        if unknown:
            raise SystemExit(f"unknown sections {unknown}; registered: "
                             f"{sorted(SECTIONS)}")
        selected = {n: SECTIONS[n] for n in names}

    t0 = time.time()
    print(f"# hot-path benchmark (smoke={args.smoke}, sections="
          f"{','.join(selected)}) on {jax.default_backend()}", flush=True)
    result = {}
    if args.sections and os.path.exists(out_path):
        # subset run: keep the other sections' entries from the existing
        # report so the file stays complete
        try:
            with open(out_path) as f:
                result = json.load(f)
        except (OSError, json.JSONDecodeError):
            result = {}
    result["meta"] = {
        "smoke": args.smoke,
        "date": time.strftime("%Y-%m-%d"),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
    }
    for name, (fn, _) in selected.items():
        result[name] = fn(args.smoke)
    result["meta"]["bench_wall_s"] = round(time.time() - t0, 2)

    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
        f.write("\n")
    print(f"# wrote {out_path} ({result['meta']['bench_wall_s']}s total)")

    # registered sections cannot rot by omission: every selected one must
    # have produced a report entry, and every parity verdict must hold
    missing = [n for n in selected
               if n not in result or result[n] is None]
    if missing:
        raise SystemExit(f"benchmark sections silently skipped: {missing}")
    violations = [n for n, (_, parity) in selected.items()
                  if parity is not None and not parity(result[n])]
    if violations:
        raise SystemExit(f"parity violation in {violations} — see JSON "
                         f"output")


if __name__ == "__main__":
    main()
