"""Shared world-building for the FL benchmarks: constellation, connectivity,
dataset, partitions, adapters, and the FedSpace regressor setup."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import connectivity as CN
from repro.core.scheduler import make_scheduler
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition, noniid_partition
from repro.data.pipeline import make_clients
from repro.fl import fedspace_setup as FS
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.simulation import run_simulation

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def build_world(*, K: int = 191, days: float = 5.0, num_train: int = 36_000,
                num_val: int = 5_304, setting: str = "iid", seed: int = 0):
    spec = CN.ConstellationSpec(num_satellites=K)
    C = CN.connectivity_sets(spec, days=days)
    data = SyntheticFmow(FmowSpec(num_train=num_train, num_val=num_val))
    if setting == "iid":
        parts = iid_partition(num_train, K, seed)
    else:
        parts = noniid_partition(data.train_zones, K, spec, days=days,
                                 seed=seed)
    adapter = MlpFmowAdapter(data, make_clients(parts))
    return spec, C, data, adapter


def build_fedspace_scheduler(adapter, *, I0=24, n_min=None, n_max=None,
                             num_candidates=5000, regressor_kind="rf",
                             pretrain_rounds=40, utility_samples=250,
                             local_steps=16, client_lr=1.0,
                             clients_per_round=24, seed=0):
    traj = FS.pretrain_trajectory(adapter, rounds=pretrain_rounds,
                                  clients_per_round=clients_per_round,
                                  local_steps=local_steps,
                                  client_lr=client_lr, seed=seed)
    reg, diag = FS.fit_utility_regressor(adapter, traj,
                                         kind=regressor_kind,
                                         n_samples=utility_samples,
                                         local_steps=local_steps,
                                         client_lr=client_lr,
                                         seed=seed)
    sched = make_scheduler("fedspace", regressor=reg, I0=I0, n_min=n_min,
                           n_max=n_max, num_candidates=num_candidates,
                           seed=seed)
    return sched, diag


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path
