"""Shared world-building for the FL benchmarks, now a thin veneer over the
declarative `repro.fl.api` layer (plus results-dir helpers).

`build_fedspace_scheduler` moved into product code
(`repro.fl.fedspace_setup`) — re-exported here for back compat.
"""
from __future__ import annotations

import json
import os

from repro.fl.api import (AdapterConfig, ConstellationConfig, DatasetConfig,
                          FLExperiment, Federation, PartitionConfig,
                          SchedulerConfig)
from repro.fl.fedspace_setup import build_fedspace_scheduler  # noqa: F401

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def world_experiment(*, K: int = 191, days: float = 5.0,
                     num_train: int = 36_000, num_val: int = 5_304,
                     noise: float = 0.9, hidden: int = 64,
                     setting: str = "iid", seed: int = 0) -> FLExperiment:
    """The benchmarks' canonical world as a declarative experiment (the
    scheduler is swapped per-scheme with `Federation.with_scheduler`)."""
    return FLExperiment(
        name=f"bench-{setting}-K{K}",
        constellation=ConstellationConfig(num_satellites=K, days=days),
        dataset=DatasetConfig(num_train=num_train, num_val=num_val,
                              noise=noise),
        partition=PartitionConfig(kind=setting),
        adapter=AdapterConfig(kind="mlp", params={"hidden": hidden}),
        scheduler=SchedulerConfig(kind="async"),
        seed=seed,
    )


def build_world(*, K: int = 191, days: float = 5.0, num_train: int = 36_000,
                num_val: int = 5_304, setting: str = "iid", seed: int = 0):
    """Back-compat tuple view (spec, C, data, adapter) of the wired world."""
    fed = Federation.from_experiment(world_experiment(
        K=K, days=days, num_train=num_train, num_val=num_val,
        setting=setting, seed=seed))
    return fed.spec, fed.C, fed.data, fed.adapter


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path
