"""Benchmark driver — one entry per paper table/figure plus the kernel
microbenchmarks and the roofline report. Prints ``name,us_per_call,derived``
CSV rows (plus human-readable sections).

Default mode is CPU-budget-friendly: Fig. 2 full-scale, Table 2 at a
reduced horizon (sync capped; full runs live in results/table2.json via
``python -m benchmarks.table2_training_time``), kernels in interpret mode,
roofline from the recorded dry-run sweep.

    PYTHONPATH=src python -m benchmarks.run [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time


def section(title):
    print(f"\n# === {title} ===", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="run the full Table-2 horizon (slow)")
    args = ap.parse_args()

    t0 = time.time()
    print("name,us_per_call,derived")

    # ------------------------------------------------ Fig. 2
    section("Fig2: connectivity statistics (191 sats, 12 GS)")
    from benchmarks import fig2_connectivity
    t = time.time()
    out = fig2_connectivity.run(days=5.0)
    print(f"fig2_connectivity,{(time.time() - t) * 1e6:.0f},"
          f"ci[{out['ci_min']}..{out['ci_max']}]_nk[{out['nk_min']:.0f}.."
          f"{out['nk_max']:.0f}]")

    # ------------------------------------------------ Table 2 / Fig 6 / 7
    section("Table2: days to 40% top-1 (reduced horizon; full in "
            "results/table2.json)")
    from benchmarks.table2_training_time import run_table2
    t = time.time()
    max_days = 20.0 if args.full else 6.0
    schemes = (["sync", "async", "fedbuff", "fedspace"] if args.full
               else ["async", "fedbuff", "fedspace"])
    rows, _ = run_table2(["noniid"], schemes, max_days=max_days)
    for r in rows:
        d = r["days_to_target"]
        print(f"table2_{r['setting']}_{r['scheme']},"
              f"{r['wall_s'] * 1e6:.0f},"
              f"days_to_40pct={d if d is not None else 'FAIL'}")

    # ------------------------------------------------ Fig. 7 summary
    section("Fig7: staleness/idleness distribution (from Table-2 runs)")
    for r in rows:
        print(f"fig7_{r['scheme']},0,hist={r['staleness_hist']}"
              f"_idle={r['idle_connections']}of{r['total_connections']}")

    # ------------------------------------------------ kernels
    section("Kernel microbenchmarks (interpret mode; TPU is the target)")
    from benchmarks.kernels_micro import rows as krows
    for name, us, derived in krows():
        print(f"{name},{us:.0f},{derived}")

    # ------------------------------------------------ hot paths
    section("Simulation hot paths (smoke shapes; committed full-shape "
            "baseline in BENCH_hotpaths.json)")
    from benchmarks.hotpaths import bench_aggregation, bench_search
    s = bench_search(smoke=True)
    print(f"hotpath_search_replan,{s['t_optimized_warm_s'] * 1e6:.0f},"
          f"speedup={s['speedup_warm']:.1f}x"
          f"_identical={s['schedule_identical']}")
    a = bench_aggregation(smoke=True)
    print(f"hotpath_aggregation,{a['t_batched_s'] * 1e6:.0f},"
          f"speedup={a['speedup']:.1f}x_bit={a['params_bit_equal']}")

    # ------------------------------------------------ roofline
    section("Roofline (from the recorded dry-run sweep)")
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.json")
    if os.path.exists(path):
        with open(path) as f:
            drs = json.load(f)
        ok = [r for r in drs if r["status"] == "ok"]
        for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
            print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
                  f"{r['time_s'] * 1e6:.0f},"
                  f"dom={r['dominant_term']}"
                  f"_c={r['t_compute_s']:.2e}_m={r['t_memory_s']:.2e}"
                  f"_x={r['t_collective_s']:.2e}")
        doms = {}
        for r in ok:
            doms[r["dominant_term"]] = doms.get(r["dominant_term"], 0) + 1
        print(f"roofline_summary,0,{doms}")
    else:
        print("roofline_missing,0,run repro.launch.sweep first")

    print(f"\n# total bench time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
