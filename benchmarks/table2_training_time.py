"""Paper Table 2 (+ Fig. 6 curves + Fig. 7 staleness/idleness histograms):
training time (simulated days) to a target top-1 accuracy for Sync / Async /
FedBuff / FedSpace over the 191-satellite, 12-ground-station constellation,
IID and Non-IID — declared once via `repro.fl.api` and raced per-scheme
with `Federation.with_scheduler`.

Calibrated world (see DESIGN.md §7): synthetic fMoW at 9.6k train samples,
62 classes, feature-MLP global model, client SGD lr=1.0, E=16 local steps —
chosen so the staleness/idleness phenomenology matches the paper (async
plateaus below the 40% target; sync is idle-dominated; buffered schemes
converge). Target accuracy = 40% top-1, as in the paper.

Usage: PYTHONPATH=src:. python -m benchmarks.table2_training_time
           [--settings iid noniid] [--schemes ...] [--max-days 20]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save_json, world_experiment
from repro.fl.api import Federation, SchedulerConfig
from repro.fl.engine import EngineConfig

TARGET_ACC = 0.40
CLIENT_LR = 1.0
LOCAL_STEPS = 16
HIDDEN = 48
NOISE = 2.2
NUM_TRAIN = 9_600
NUM_VAL = 2_000
EVAL_EVERY = 24           # 6 simulated hours
DEFAULT_SCHEMES = ["sync", "async", "fedbuff", "fedspace"]


def build_federation(setting: str, seed: int = 0) -> Federation:
    exp = world_experiment(K=191, days=5.0, num_train=NUM_TRAIN,
                           num_val=NUM_VAL, noise=NOISE, hidden=HIDDEN,
                           setting=setting, seed=seed)
    exp.train = EngineConfig(local_steps=LOCAL_STEPS, client_lr=CLIENT_LR,
                             eval_every=EVAL_EVERY, target_acc=TARGET_ACC,
                             stop_at_target=True)
    return Federation.from_experiment(exp)


class _RandomUtility:
    """Ablation oracle: FedSpace's aggregation *rate* without its
    utility-driven placement."""

    def predict(self, X):
        rng = np.random.default_rng(int(abs(X.sum()) * 1e4) % 2**31)
        return rng.random(len(X))


def scheme_config(name: str, seed: int = 0) -> SchedulerConfig:
    if name == "fedspace":
        return SchedulerConfig(
            "fedspace",
            params={"I0": 24, "n_min": None, "n_max": None,  # from û
                    "num_candidates": 3000, "seed": seed},
            setup={"pretrain_rounds": 40, "utility_samples": 200})
    if name == "fedspace-random":
        return SchedulerConfig(
            "fedspace", params={"regressor": _RandomUtility(), "I0": 24,
                                "n_min": 4, "n_max": 8,
                                "num_candidates": 1, "seed": seed})
    if name == "fedbuff":
        return SchedulerConfig("fedbuff", params={"M": 96})
    if name == "periodic":
        return SchedulerConfig("periodic", params={"period": 4})
    return SchedulerConfig(name)


def run_table2(settings, schemes, *, max_days: float = 20.0, seed: int = 0):
    rows = []
    curves = {}
    max_windows = int(max_days * 96)
    for setting in settings:
        base = build_federation(setting, seed)
        base.experiment.train.max_windows = max_windows
        base.experiment.train.repeat_connectivity = 0   # auto-tile C
        for scheme in schemes:
            t0 = time.time()
            fed = base.with_scheduler(scheme_config(scheme, seed))
            res = fed.run()
            diag = fed.scheduler_diag
            row = {
                "setting": setting, "scheme": scheme,
                "target_acc": TARGET_ACC,
                "days_to_target": res.time_to_target_days,
                "best_acc": max(res.accuracy),
                "global_updates": res.num_global_updates,
                "aggregated_gradients": res.num_aggregated_gradients,
                "idle_connections": res.idle_connections,
                "total_connections": res.total_connections,
                "staleness_hist": res.staleness_hist.tolist(),
                "wall_s": round(time.time() - t0, 1),
                **({"regressor": diag} if diag else {}),
            }
            rows.append(row)
            curves[f"{setting}/{scheme}"] = {
                "windows": res.eval_windows,
                "days": [res.days(w) for w in res.eval_windows],
                "accuracy": res.accuracy,
            }
            d = row["days_to_target"]
            print(f"[{setting:6s}] {scheme:16s} days_to_{TARGET_ACC:.0%}="
                  f"{d if d is not None else 'FAIL':>6} best="
                  f"{row['best_acc']:.3f} updates="
                  f"{row['global_updates']} idle={row['idle_connections']}"
                  f" ({row['wall_s']}s)", flush=True)
    return rows, curves


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--settings", nargs="+", default=["iid", "noniid"])
    ap.add_argument("--schemes", nargs="+", default=DEFAULT_SCHEMES)
    ap.add_argument("--max-days", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows, curves = run_table2(args.settings, args.schemes,
                              max_days=args.max_days, seed=args.seed)
    tag = f"_{args.tag}" if args.tag else ""
    save_json(f"table2{tag}.json", rows)
    save_json(f"fig6_curves{tag}.json", curves)
    print("saved results/table2%s.json" % tag)


if __name__ == "__main__":
    main()
