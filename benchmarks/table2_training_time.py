"""Paper Table 2 (+ Fig. 6 curves + Fig. 7 staleness/idleness histograms):
training time (simulated days) to a target top-1 accuracy for Sync / Async /
FedBuff / FedSpace over the 191-satellite, 12-ground-station constellation,
IID and Non-IID.

Calibrated world (see DESIGN.md §7): synthetic fMoW at 9.6k train samples,
62 classes, feature-MLP global model, client SGD lr=1.0, E=16 local steps —
chosen so the staleness/idleness phenomenology matches the paper (async
plateaus below the 40% target; sync is idle-dominated; buffered schemes
converge). Target accuracy = 40% top-1, as in the paper.

Usage: PYTHONPATH=src:. python -m benchmarks.table2_training_time
           [--settings iid noniid] [--schemes ...] [--max-days 20]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import build_fedspace_scheduler, build_world, \
    save_json
from repro.core.scheduler import make_scheduler
from repro.fl.simulation import run_simulation

TARGET_ACC = 0.40
CLIENT_LR = 1.0
LOCAL_STEPS = 16
HIDDEN = 48
NOISE = 2.2
NUM_TRAIN = 9_600
NUM_VAL = 2_000
EVAL_EVERY = 24           # 6 simulated hours
DEFAULT_SCHEMES = ["sync", "async", "fedbuff", "fedspace"]


def build_adapter(setting: str, seed: int = 0):
    from repro.core import connectivity as CN
    from repro.data.fmow import FmowSpec, SyntheticFmow
    from repro.data.partition import iid_partition, noniid_partition
    from repro.data.pipeline import make_clients
    from repro.fl.adapters import MlpFmowAdapter

    spec = CN.ConstellationSpec(num_satellites=191)
    C = CN.connectivity_sets(spec, days=5.0)
    data = SyntheticFmow(FmowSpec(num_train=NUM_TRAIN, num_val=NUM_VAL,
                                  noise=NOISE))
    parts = (iid_partition(NUM_TRAIN, 191, seed) if setting == "iid" else
             noniid_partition(data.train_zones, 191, spec, days=5.0,
                              seed=seed))
    adapter = MlpFmowAdapter(data, make_clients(parts), hidden=HIDDEN)
    return C, adapter


def make_scheme(name: str, adapter, seed: int = 0):
    if name == "fedspace":
        sched, diag = build_fedspace_scheduler(
            adapter, I0=24, n_min=None, n_max=None,   # inferred from û
            num_candidates=3000, pretrain_rounds=40,
            utility_samples=200, seed=seed)
        # regenerate regressor with matched local hyperparameters
        return sched, diag
    if name == "fedbuff":
        return make_scheduler("fedbuff", M=96), {}
    if name == "periodic":
        return make_scheduler("periodic", period=4), {}
    if name == "fedspace-random":
        # ablation: FedSpace's aggregation *rate* without its utility-driven
        # placement — random n_agg ~ U[4,8] positions per window of 24
        class _RandomUtility:
            def predict(self, X):
                rng = np.random.default_rng(int(abs(X.sum()) * 1e4) % 2**31)
                return rng.random(len(X))
        return make_scheduler("fedspace", regressor=_RandomUtility(), I0=24,
                              n_min=4, n_max=8, num_candidates=1,
                              seed=seed), {}
    return make_scheduler(name), {}


def run_table2(settings, schemes, *, max_days: float = 20.0, seed: int = 0):
    rows = []
    curves = {}
    max_windows = int(max_days * 96)
    for setting in settings:
        C, adapter = build_adapter(setting, seed)
        repeat = int(np.ceil(max_windows / C.shape[0]))
        for scheme in schemes:
            t0 = time.time()
            sched, diag = make_scheme(scheme, adapter, seed)
            res = run_simulation(
                C, adapter, sched, client_lr=CLIENT_LR,
                local_steps=LOCAL_STEPS, eval_every=EVAL_EVERY,
                target_acc=TARGET_ACC, max_windows=max_windows,
                repeat_connectivity=repeat, stop_at_target=True, seed=seed)
            row = {
                "setting": setting, "scheme": scheme,
                "target_acc": TARGET_ACC,
                "days_to_target": res.time_to_target_days,
                "best_acc": max(res.accuracy),
                "global_updates": res.num_global_updates,
                "aggregated_gradients": res.num_aggregated_gradients,
                "idle_connections": res.idle_connections,
                "total_connections": res.total_connections,
                "staleness_hist": res.staleness_hist.tolist(),
                "wall_s": round(time.time() - t0, 1),
                **({"regressor": diag} if diag else {}),
            }
            rows.append(row)
            curves[f"{setting}/{scheme}"] = {
                "windows": res.eval_windows,
                "days": [res.days(w) for w in res.eval_windows],
                "accuracy": res.accuracy,
            }
            d = row["days_to_target"]
            print(f"[{setting:6s}] {scheme:16s} days_to_{TARGET_ACC:.0%}="
                  f"{d if d is not None else 'FAIL':>6} best="
                  f"{row['best_acc']:.3f} updates="
                  f"{row['global_updates']} idle={row['idle_connections']}"
                  f" ({row['wall_s']}s)", flush=True)
    return rows, curves


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--settings", nargs="+", default=["iid", "noniid"])
    ap.add_argument("--schemes", nargs="+", default=DEFAULT_SCHEMES)
    ap.add_argument("--max-days", type=float, default=20.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows, curves = run_table2(args.settings, args.schemes,
                              max_days=args.max_days, seed=args.seed)
    tag = f"_{args.tag}" if args.tag else ""
    save_json(f"table2{tag}.json", rows)
    save_json(f"fig6_curves{tag}.json", curves)
    print("saved results/table2%s.json" % tag)


if __name__ == "__main__":
    main()
