"""Paper Fig. 2: connectivity statistics of the 191-satellite / 12-GS
constellation — |C_i| over a day and the per-satellite contacts/day
histogram. Validates our propagator's heterogeneity against the paper's
qualitative ranges (|C_i| in [4, 68]; n_k in [5, 19])."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json
from repro.core import connectivity as CN


def run(days: float = 5.0):
    spec = CN.ConstellationSpec()
    C = CN.connectivity_sets(spec, days=days)
    st = CN.connectivity_stats(C)
    hist_nk, edges = np.histogram(st["contacts_per_day"],
                                  bins=np.arange(0, 32))
    out = {
        "num_satellites": spec.num_satellites,
        "num_ground_stations": len(spec.ground_stations),
        "ci_min": st["ci_min"], "ci_max": st["ci_max"],
        "ci_mean": round(st["ci_mean"], 2),
        "nk_min": st["nk_min"], "nk_max": st["nk_max"],
        "nk_mean": round(st["nk_mean"], 2),
        "ci_series_day1": st["sizes"][:96].tolist(),
        "nk_histogram": {"counts": hist_nk.tolist(),
                         "edges": edges.tolist()},
        "paper_reference": {"ci_range": [4, 68], "nk_range": [5, 19]},
    }
    return out


def main():
    out = run()
    save_json("fig2_connectivity.json", out)
    print(f"|C_i|: min={out['ci_min']} max={out['ci_max']} "
          f"mean={out['ci_mean']} (paper: 4..68)")
    print(f"n_k/day: min={out['nk_min']} max={out['nk_max']} "
          f"mean={out['nk_mean']} (paper: 5..19)")


if __name__ == "__main__":
    main()
