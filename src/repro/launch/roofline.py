"""Roofline-term extraction from compiled dry-run artifacts.

``compiled.cost_analysis()`` on the CPU backend visits while bodies ONCE, so
scanned layers / microbatches / q-chunks would be undercounted by 10-100x.
This module therefore re-derives FLOPs, HBM-traffic and collective bytes from
the post-optimization HLO text itself:

  * every instruction's result shape is recorded; operand shapes resolve by
    name (post-opt HLO omits inline operand types for locals);
  * execution multipliers propagate through the computation graph — while
    bodies scale by ``backend_config known_trip_count`` (fallback: the
    largest constant in the loop condition), calls/fusions scale by 1;
  * FLOPs: dot = 2 * prod(result dims) * prod(contracting dims);
  * HBM bytes: sum of (result + operand) bytes over *executed* top-level
    instructions (fusion bodies excluded — the fusion instruction itself is
    the HBM I/O boundary, which is exactly what fusion means);
  * collective bytes: operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Post-opt HLO is per-shard, so analyzer outputs are per-device; the report
scales to global (x chips) so the three terms follow the mandated formulas:

    compute    = HLO_FLOPs  / (chips * PEAK_FLOPS)
    memory     = HLO_bytes  / (chips * HBM_BW)
    collective = coll_bytes / (chips * ICI_BW)
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# TPU v5e-class hardware constants (per chip).
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "u1": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"\b(pred|token|bf16|f16|f32|f64|c64|c128|[su]\d+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s*->")
_REF_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')


def _shape_list_bytes(type_str: str) -> Tuple[int, List[Tuple[str, str]]]:
    shapes = _SHAPE_RE.findall(type_str)
    total = 0
    for dt, dims in shapes:
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total, shapes


def _dims_of(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Instr:
    name: str
    kind: str
    result_type: str
    result_bytes: int
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr] = field(default_factory=list)
    param_types: Dict[str, str] = field(default_factory=dict)


class HloAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._build_multipliers()

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            mc = _COMP_RE.match(line)
            if mc and (line.endswith("{") or "{" in line):
                cur = Computation(name=mc.group(2),
                                  is_entry=bool(mc.group(1)))
                # parameter types from the signature
                sig = mc.group(3)
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,]+(?:\[[\d,]*\])?"
                                      r"(?:\{[^}]*\})?)", sig):
                    cur.param_types[pm.group(1)] = pm.group(2)
                self.comps[cur.name] = cur
                if cur.is_entry:
                    self.entry = cur.name
                continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, rtype, kind = mi.group(1), mi.group(2), mi.group(3)
            rbytes, _ = _shape_list_bytes(rtype)
            # operand names: %refs inside the first paren group
            after = line[mi.end():]
            depth, i = 1, 0
            while i < len(after) and depth:
                if after[i] == "(":
                    depth += 1
                elif after[i] == ")":
                    depth -= 1
                i += 1
            argstr = after[:i - 1] if i else after
            operands = re.findall(r"%([\w\.\-]+)", argstr)
            cur.instrs.append(Instr(name, kind, rtype, rbytes, operands,
                                    line))

    # ------------------------------------------------------------------
    def _build_multipliers(self):
        # call edges: (caller, callee, factor)
        edges: Dict[str, List[Tuple[str, float]]] = {}
        for comp in self.comps.values():
            for ins in comp.instrs:
                refs = _REF_RE.findall(ins.line)
                if not refs:
                    continue
                factor = 1.0
                trip_m = _TRIP_RE.search(ins.line)
                if ins.kind == "while":
                    if trip_m:
                        factor = float(trip_m.group(1))
                    else:
                        factor = self._trip_from_condition(ins.line)
                for callee in refs:
                    # condition computations execute trip+1 times; close
                    # enough to trip for our purposes.
                    edges.setdefault(callee, []).append((comp.name, factor))
        self.mult: Dict[str, float] = {}

        def mult_of(name: str, stack=()) -> float:
            if name in self.mult:
                return self.mult[name]
            if name == self.entry:
                return 1.0
            if name in stack:   # recursion guard
                return 1.0
            callers = edges.get(name, [])
            if not callers:
                m = 1.0 if name == self.entry else 0.0
            else:
                m = sum(mult_of(c, stack + (name,)) * f for c, f in callers)
            self.mult[name] = m
            return m

        for name in self.comps:
            self.mult[name] = mult_of(name)
        if self.entry:
            self.mult[self.entry] = 1.0

        # fusion/reduce bodies: excluded from the bytes pass
        self.fused_bodies = set()
        for comp in self.comps.values():
            for ins in comp.instrs:
                if ins.kind in ("fusion", "reduce", "reduce-window", "sort",
                                "map", "scatter", "select-and-scatter",
                                "all-reduce", "reduce-scatter"):
                    for callee in _REF_RE.findall(ins.line):
                        self.fused_bodies.add(callee)

    def _trip_from_condition(self, line: str) -> float:
        m = re.search(r"condition=%?([\w\.\-]+)", line)
        if not m or m.group(1) not in self.comps:
            return 1.0
        best = 1.0
        for ins in self.comps[m.group(1)].instrs:
            for c in re.findall(r"constant\((\d+)\)", ins.line):
                best = max(best, float(c))
        return best

    # ------------------------------------------------------------------
    def _operand_bytes(self, comp: Computation, ins: Instr,
                       index: Dict[str, int]) -> int:
        total = 0
        for op in ins.operands:
            if op in index:
                total += index[op]
            elif op in comp.param_types:
                b, _ = _shape_list_bytes(comp.param_types[op])
                total += b
        return total

    def flops(self) -> float:
        total = 0.0
        for comp in self.comps.values():
            m = self.mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            index = {i.name: i for i in comp.instrs}
            for ins in comp.instrs:
                if ins.kind == "dot":
                    rdims = _dims_of(ins.result_type)
                    cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}",
                                   ins.line)
                    contract = 1
                    if cd and ins.operands:
                        lhs = ins.operands[0]
                        if lhs in index:
                            ldims = _dims_of(index[lhs].result_type)
                        else:
                            ldims = _dims_of(comp.param_types.get(lhs, ""))
                        for di in (cd.group(1).split(",") if cd.group(1)
                                   else []):
                            di = int(di)
                            if di < len(ldims):
                                contract *= ldims[di]
                    r = 1
                    for d in rdims:
                        r *= d
                    total += 2.0 * r * contract * m
                elif ins.kind == "convolution":
                    rdims = _dims_of(ins.result_type)
                    r = 1
                    for d in rdims:
                        r *= d
                    # approx: 2 * out_elems * kernel_elems_per_output
                    if ins.operands and len(ins.operands) > 1:
                        kname = ins.operands[1]
                        kdims = _dims_of(
                            index[kname].result_type if kname in index
                            else comp.param_types.get(kname, ""))
                        k = 1
                        for d in kdims[:-1]:
                            k *= d
                        total += 2.0 * r * k * m
        return total

    def hbm_bytes(self) -> float:
        skip_kinds = {"tuple", "get-tuple-element", "parameter", "constant",
                      "bitcast", "after-all", "partition-id", "replica-id"}
        total = 0.0
        for comp in self.comps.values():
            if comp.name in self.fused_bodies:
                continue
            m = self.mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            rbytes_index = {}
            for ins in comp.instrs:
                rbytes_index[ins.name] = ins.result_bytes
            for ins in comp.instrs:
                if ins.kind in skip_kinds:
                    continue
                if (ins.kind == "dynamic-update-slice"
                        or "dynamic-update-slice" in ins.line.split("=")[0]
                        or (ins.kind == "fusion"
                            and "dynamic-update-slice" in ins.name)):
                    # in-place update: traffic = read update + write slice,
                    # not the whole aliased buffer
                    small = sum(
                        b for b in (rbytes_index.get(op)
                                    or _shape_list_bytes(
                                        comp.param_types.get(op, ""))[0]
                                    for op in ins.operands)
                        if b < ins.result_bytes)
                    total += 2.0 * small * m
                    continue
                total += (ins.result_bytes
                          + self._operand_bytes_fast(comp, ins, rbytes_index)
                          ) * m
        return total

    def _operand_bytes_fast(self, comp, ins, rbytes_index) -> int:
        total = 0
        for op in ins.operands:
            if op in rbytes_index:
                total += rbytes_index[op]
            elif op in comp.param_types:
                b, _ = _shape_list_bytes(comp.param_types[op])
                total += b
        return total

    def collective_bytes(self) -> "CollectiveStats":
        stats = CollectiveStats()
        for comp in self.comps.values():
            m = self.mult.get(comp.name, 0.0)
            if m == 0.0 or comp.name in self.fused_bodies:
                continue
            rbytes_index = {i.name: i.result_bytes for i in comp.instrs}
            for ins in comp.instrs:
                kind = ins.kind.replace("-start", "")
                if kind not in COLLECTIVES:
                    continue
                b = self._operand_bytes_fast(comp, ins, rbytes_index)
                if b == 0:
                    b = ins.result_bytes
                stats.total_bytes += b * m
                stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + b * m
                stats.count += 1
        return stats


@dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_kind: dict = field(default_factory=dict)
    count: int = 0


# ---------------------------------------------------------------------------
# Report assembly


def analyze(hlo_text: str, chips: int) -> dict:
    """Per-device analysis scaled to global; terms per the mandated
    formulas."""
    an = HloAnalysis(hlo_text)
    dev_flops = an.flops()
    dev_bytes = an.hbm_bytes()
    coll = an.collective_bytes()
    glob_flops = dev_flops * chips
    glob_bytes = dev_bytes * chips
    glob_coll = coll.total_bytes * chips
    return {
        "hlo_flops": glob_flops,
        "hlo_bytes": glob_bytes,
        "collective_bytes": glob_coll,
        "collective_by_kind": {k: v * chips for k, v in coll.by_kind.items()},
        "collective_count": coll.count,
        "t_compute_s": glob_flops / (chips * PEAK_FLOPS),
        "t_memory_s": glob_bytes / (chips * HBM_BW),
        "t_collective_s": glob_coll / (chips * ICI_BW),
    }


def model_flops(cfg, shape, num_params_active: float, num_params_total: float
                ) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode)."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * num_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * num_params_active * tokens
    return 2.0 * num_params_active * shape.global_batch


def count_params(params_shape) -> int:
    import jax
    return int(sum(x.size for x in jax.tree.leaves(params_shape)))


def count_active_params(cfg, params_shape) -> float:
    """Active params per token: total minus inactive expert fraction."""
    import jax
    total = count_params(params_shape)
    if not cfg.num_experts:
        return float(total)
    expert = 0

    def visit(path, leaf):
        nonlocal expert
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("w_gate", "w_up", "w_down") and leaf.ndim >= 3:
            expert += leaf.size
        return leaf

    jax.tree_util.tree_map_with_path(visit, params_shape)
    frac = cfg.experts_per_token / cfg.num_experts
    return float(total - expert + expert * frac)
