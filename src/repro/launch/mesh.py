"""Production mesh construction.

Defined as functions (not module constants) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while tests/benches must see the single real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1x1 mesh on the real devices — for smoke-scale runs of the same
    pjit code paths on CPU."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
