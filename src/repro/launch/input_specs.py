"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation. The dry-run lowers against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import VISION_DIM

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision":
        st = S - cfg.num_image_tokens
        return {
            "tokens": SDS((B, st), jnp.int32),
            "labels": SDS((B, st), jnp.int32),
            "image_embeds": SDS((B, cfg.num_image_tokens, VISION_DIM),
                                jnp.float32),
        }
    if cfg.is_encoder_decoder:
        return {
            "tokens": SDS((B, cfg.decoder_prompt), jnp.int32),
            "labels": SDS((B, cfg.decoder_prompt), jnp.int32),
            "frames": SDS((B, S, cfg.d_model), jnp.float32),
        }
    return {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_token_spec(cfg: ModelConfig, shape: ShapeConfig):
    return SDS((shape.global_batch, 1), jnp.int32)


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Whether this (arch, shape) pair runs (DESIGN.md long_500k policy)."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return False
    return True
