"""Run the full dry-run sweep: every (arch x input-shape x mesh) combination
in fresh subprocesses (XLA flags lock at first init), skipping combinations
already recorded as ok. Usage:

    PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun.json \
        --jobs 4 [--mesh single multi]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from concurrent.futures import ThreadPoolExecutor

ARCHS = [
    "mamba2-370m", "h2o-danube-1.8b", "phi-3-vision-4.2b",
    "qwen3-moe-30b-a3b", "qwen3-8b", "gemma3-12b", "recurrentgemma-9b",
    "minitron-4b", "whisper-base", "mixtral-8x7b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def existing(out):
    try:
        with open(out) as f:
            return {(r["arch"], r["shape"], r["mesh"]): r["status"]
                    for r in json.load(f)}
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--mesh", nargs="+", default=["single", "multi"])
    ap.add_argument("--archs", nargs="+", default=ARCHS)
    ap.add_argument("--shapes", nargs="+", default=SHAPES)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    done = {} if args.force else existing(args.out)
    todo = []
    for mesh in args.mesh:
        for arch in args.archs:
            for shape in args.shapes:
                if done.get((arch, shape, mesh)) in ("ok", "skipped"):
                    continue
                todo.append((arch, shape, mesh))
    print(f"{len(todo)} combinations to run", flush=True)

    def run(combo):
        arch, shape, mesh = combo
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", args.out]
        env = dict(os.environ)
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=1800)
        status = "ok" if r.returncode == 0 else "FAIL"
        print(f"[{status}] {arch} {shape} {mesh}", flush=True)
        if r.returncode != 0:
            print(r.stdout[-1500:], r.stderr[-500:], flush=True)
        return status

    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        results = list(ex.map(run, todo))
    fails = results.count("FAIL")
    print(f"done: {len(results) - fails} ok, {fails} failed", flush=True)


if __name__ == "__main__":
    main()
