import os
import sys
_flags = "--xla_force_host_platform_device_count=512"
if "--strict-dtypes" in sys.argv:
    # keep bf16 collectives in bf16 (XLA's excess-precision pass otherwise
    # promotes convert->psum->convert chains back to f32; TPU backends keep
    # native bf16 all-reduces) — used by the §Perf agg hillclimb
    sys.argv.remove("--strict-dtypes")
    _flags += " --xla_allow_excess_precision=false"
os.environ["XLA_FLAGS"] = _flags

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) combination on
the production meshes, record memory/cost/collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single --out results/dryrun.json

Each invocation runs one combination in a fresh process (XLA device-count
flags lock at first jax init; a fresh process also bounds compile memory) and
appends its record to the JSON results file.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES, get_config
from repro.launch import input_specs as IS
from repro.launch import roofline as RL
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            num_micro=None, q_chunk=512, moe_groups=1,
            save_hlo=None) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "skipped", "time_s": 0.0}
    if not IS.applicable(cfg, shape):
        rec["reason"] = "long_500k requires sub-quadratic decode (DESIGN.md)"
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = 512 if mesh_kind == "multi" else 256
    try:
        with mesh:
            fn, args, in_sh, out_sh = ST.build(cfg, shape, mesh,
                                               num_micro=num_micro,
                                               q_chunk=q_chunk,
                                               moe_groups=moe_groups)
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        terms = RL.analyze(hlo, chips)
        terms["xla_cost_flops_unscaled"] = float(cost.get("flops", 0.0))
        params_shape = args[0]
        n_total = RL.count_params(params_shape)
        n_active = RL.count_active_params(cfg, params_shape)
        mflops = RL.model_flops(cfg, shape, n_active, n_total)
        rec.update({
            "status": "ok",
            "chips": chips,
            "params_total": n_total,
            "params_active": n_active,
            "model_flops": mflops,
            "useful_flops_ratio": (mflops / terms["hlo_flops"]
                                   if terms["hlo_flops"] else None),
            **terms,
        })
        if mem is not None:
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            }
        dom = max(("compute", "memory", "collective"),
                  key=lambda k: rec[f"t_{k}_s"])
        rec["dominant_term"] = dom
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — a dry-run failure is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["time_s"] = round(time.time() - t0, 1)
    return rec


def append_result(path: str, rec: dict):
    import fcntl
    lockpath = path + ".lock"
    lock = open(lockpath, "w")
    fcntl.flock(lock, fcntl.LOCK_EX)
    try:
        _append_locked(path, rec)
    finally:
        fcntl.flock(lock, fcntl.LOCK_UN)
        lock.close()


def _append_locked(path: str, rec: dict):
    try:
        with open(path) as f:
            data = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        data = []
    data = [r for r in data
            if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                    and r["mesh"] == rec["mesh"])]
    data.append(rec)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--num-micro", type=int, default=None)
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--moe-groups", type=int, default=1,
                    help="-1 = auto (one routing group per sequence); "
                         "-2 = expert-parallel shard_map")
    ap.add_argument("--remat-attn", action="store_true",
                    help="checkpoint the per-q-chunk attention body")
    ap.add_argument("--opt-decode", action="store_true",
                    help="model-shard cache feature dims + sharded-vocab "
                         "argmax (EXPERIMENTS.md hillclimb B)")
    ap.add_argument("--variant", default="",
                    help="tag appended to the record key")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    from repro.models.attention import remat_attention_chunks
    if args.opt_decode:
        from repro import sharding as _Sh
        _Sh.DECODE_OPT = True
    with remat_attention_chunks(args.remat_attn):
        rec = run_one(args.arch, args.shape, args.mesh,
                      num_micro=args.num_micro, q_chunk=args.q_chunk,
                      moe_groups=args.moe_groups, save_hlo=args.save_hlo)
    if args.variant:
        rec["shape"] = rec["shape"] + "+" + args.variant
    append_result(args.out, rec)
    drop = {"traceback"}
    print(json.dumps({k: v for k, v in rec.items() if k not in drop},
                     indent=1))
    if rec["status"] == "error":
        print(rec.get("traceback", ""))
        raise SystemExit(1)


if __name__ == "__main__":
    main()
