"""FL launcher: run the FedSpace protocol (or any baseline scheduler) over
the satellite constellation — the paper's system as a deployable driver.

    PYTHONPATH=src python -m repro.launch.fl_train --scheduler fedspace \
        --setting noniid --days 10 --target-acc 0.4
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import connectivity as CN
from repro.core.scheduler import make_scheduler
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition, noniid_partition
from repro.data.pipeline import make_clients
from repro.fl.adapters import DenseNetFmowAdapter, MlpFmowAdapter
from repro.fl.simulation import run_simulation


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="fedspace",
                    choices=["sync", "async", "fedbuff", "fedspace",
                             "periodic"])
    ap.add_argument("--setting", default="noniid",
                    choices=["iid", "noniid"])
    ap.add_argument("--model", default="mlp",
                    choices=["mlp", "densenet"])
    ap.add_argument("--satellites", type=int, default=191)
    ap.add_argument("--days", type=float, default=10.0)
    ap.add_argument("--target-acc", type=float, default=0.40)
    ap.add_argument("--client-lr", type=float, default=1.0)
    ap.add_argument("--local-steps", type=int, default=16)
    ap.add_argument("--num-train", type=int, default=9600)
    ap.add_argument("--M", type=int, default=96, help="FedBuff buffer")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    spec = CN.ConstellationSpec(num_satellites=args.satellites)
    C = CN.connectivity_sets(spec, days=min(args.days, 5.0))
    data = SyntheticFmow(FmowSpec(num_train=args.num_train,
                                  num_val=args.num_train // 5, noise=2.2))
    parts = (iid_partition(args.num_train, args.satellites, args.seed)
             if args.setting == "iid" else
             noniid_partition(data.train_zones, args.satellites, spec,
                              days=5.0, seed=args.seed))
    cls = MlpFmowAdapter if args.model == "mlp" else DenseNetFmowAdapter
    kw = {"hidden": 48} if args.model == "mlp" else {}
    adapter = cls(data, make_clients(parts), **kw)

    if args.scheduler == "fedspace":
        from benchmarks.common import build_fedspace_scheduler  # noqa: E501 — reuse calibrated setup
        sched, diag = build_fedspace_scheduler(
            adapter, local_steps=args.local_steps,
            client_lr=args.client_lr, seed=args.seed)
        print(f"utility regressor: {diag}")
    else:
        sched = make_scheduler(args.scheduler, M=args.M)

    repeat = max(1, int(np.ceil(args.days * 96 / C.shape[0])))
    res = run_simulation(C, adapter, sched, client_lr=args.client_lr,
                         local_steps=args.local_steps, eval_every=24,
                         target_acc=args.target_acc,
                         max_windows=int(args.days * 96),
                         repeat_connectivity=repeat, seed=args.seed)
    summary = res.summary()
    print(json.dumps(summary, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "accuracy": res.accuracy,
                       "eval_windows": res.eval_windows}, f, indent=1)


if __name__ == "__main__":
    main()
