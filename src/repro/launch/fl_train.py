"""FL launcher: run the FedSpace protocol (or any registered scheduler)
over the satellite constellation — the paper's system as a deployable
driver, built entirely through the declarative `repro.fl.api` layer.

    PYTHONPATH=src python -m repro.launch.fl_train --scheduler fedspace \
        --setting noniid --days 10 --target-acc 0.4

Any scheduler registered via `@register_scheduler` is selectable by name;
`--metrics-jsonl` streams eval metrics live to a JSONL file.
"""
from __future__ import annotations

import argparse
import json

from repro.fl.api import (AdapterConfig, ConstellationConfig, DatasetConfig,
                          FLExperiment, Federation, PartitionConfig,
                          SchedulerConfig)
from repro.fl.callbacks import JsonlMetricsCallback, ProgressCallback
from repro.fl.engine import EngineConfig
from repro.fl.registry import ADAPTERS, SCHEDULERS


def build_experiment(args) -> FLExperiment:
    scheduler = SchedulerConfig(kind=args.scheduler)
    if args.scheduler == "fedbuff":
        scheduler.params["M"] = args.M
    if args.scheduler == "fedspace":
        scheduler.setup = {"local_steps": args.local_steps,
                           "client_lr": args.client_lr}
    return FLExperiment(
        name=f"fl_train-{args.scheduler}-{args.setting}",
        constellation=ConstellationConfig(
            num_satellites=args.satellites, days=min(args.days, 5.0)),
        dataset=DatasetConfig(num_train=args.num_train,
                              num_val=args.num_train // 5, noise=2.2),
        partition=PartitionConfig(kind=args.setting),
        adapter=AdapterConfig(
            kind=args.model,
            params={"hidden": 48} if args.model == "mlp" else {}),
        scheduler=scheduler,
        train=EngineConfig(local_steps=args.local_steps,
                           client_lr=args.client_lr, eval_every=24,
                           target_acc=args.target_acc,
                           max_windows=int(args.days * 96),
                           repeat_connectivity=0),   # auto-tile C
        seed=args.seed,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="fedspace",
                    choices=SCHEDULERS.names())
    ap.add_argument("--setting", default="noniid",
                    choices=["iid", "noniid"])
    ap.add_argument("--model", default="mlp", choices=ADAPTERS.names())
    ap.add_argument("--satellites", type=int, default=191)
    ap.add_argument("--days", type=float, default=10.0)
    ap.add_argument("--target-acc", type=float, default=0.40)
    ap.add_argument("--client-lr", type=float, default=1.0)
    ap.add_argument("--local-steps", type=int, default=16)
    ap.add_argument("--num-train", type=int, default=9600)
    ap.add_argument("--M", type=int, default=96, help="FedBuff buffer")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-jsonl", default=None,
                    help="stream eval metrics to this JSONL file")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    fed = Federation.from_experiment(build_experiment(args))
    if fed.scheduler_diag:
        print(f"utility regressor: {fed.scheduler_diag}")

    callbacks = [ProgressCallback()]
    if args.metrics_jsonl:
        callbacks.append(JsonlMetricsCallback(args.metrics_jsonl))
    res = fed.run(callbacks=callbacks)

    summary = res.summary()
    print(json.dumps(summary, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"summary": summary, "accuracy": res.accuracy,
                       "eval_windows": res.eval_windows}, f, indent=1)


if __name__ == "__main__":
    main()
