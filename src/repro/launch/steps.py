"""pjit-able step functions: train (AdamW + microbatch accumulation + remat),
prefill, and decode, with their sharding spec trees for a given mesh.

These are the functions the dry-run lowers for every (arch x shape x mesh)
combination, and the same code paths the CPU smoke tests execute on a 1x1
mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as Sh
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import input_specs as IS
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, apply_updates, \
    clip_by_global_norm


def default_num_micro(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Pick a microbatch count so each data shard sees ~1 sequence per
    microbatch (bounds activation memory at long seq)."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    m = max(1, shape.global_batch // dp)
    return min(m, 16)


# ---------------------------------------------------------------------------
# Train


def make_train_step(cfg: ModelConfig, mesh: Mesh, *, num_micro: int = 1,
                    lr: float = 3e-4, q_chunk: int = 512,
                    aux_weight: float = 0.01, clip_norm: float = 1.0,
                    moe_groups: int = 1):
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    moe_ep = None
    if moe_groups == -2:
        moe_ep = (mesh, dp if isinstance(dp, tuple) else (dp,))

    def loss_fn(params, mb):
        logits, aux = T.forward(params, mb, cfg, q_chunk=q_chunk, remat=True,
                                moe_groups=max(moe_groups, 1),
                                moe_ep=moe_ep)
        loss = T.lm_loss(logits, mb["labels"])
        return loss + aux_weight * aux

    def train_step(params, opt_state, batch):
        if num_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((num_micro, x.shape[0] // num_micro)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, P(dp))), mb)
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)),
                                            micro)
            grads = jax.tree.map(lambda g: g / num_micro, grads)
            loss = loss / num_micro
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = adamw_update(grads, opt_state, params, lr)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def train_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Returns (abstract_args, in_shardings, out_shardings) for train_step."""
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    opt_shape = jax.eval_shape(adamw_init, params_shape)
    batch_shape = IS.train_batch_specs(cfg, shape)

    pspecs = Sh.param_specs(params_shape, cfg, mesh)
    ospecs = Sh.opt_state_specs(opt_shape, pspecs, cfg, mesh)
    bspecs = Sh.batch_specs(batch_shape, mesh)
    metric_specs = {"loss": P(), "grad_norm": P()}

    args = (params_shape, opt_shape, batch_shape)
    in_sh = (Sh.to_named(pspecs, mesh), Sh.to_named(ospecs, mesh),
             Sh.to_named(bspecs, mesh))
    out_sh = (Sh.to_named(pspecs, mesh), Sh.to_named(ospecs, mesh),
              Sh.to_named(metric_specs, mesh))
    return args, in_sh, out_sh


# ---------------------------------------------------------------------------
# Prefill


def make_prefill_step(cfg: ModelConfig, *, q_chunk: int = 512,
                      moe_groups: int = 1):
    def prefill_step(params, batch):
        logits, _ = T.forward(params, batch, cfg, q_chunk=q_chunk,
                              remat=True, moe_groups=moe_groups)
        return logits[:, -1, :]   # next-token logits

    return prefill_step


def prefill_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    batch_shape = IS.prefill_batch_specs(cfg, shape)
    pspecs = Sh.param_specs(params_shape, cfg, mesh)
    bspecs = Sh.batch_specs(batch_shape, mesh)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    ndp = 1
    for a in dp:
        ndp *= mesh.shape[a]
    bspec = dp if shape.global_batch % ndp == 0 else None
    vspec = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    out = P(bspec, vspec)
    args = (params_shape, batch_shape)
    in_sh = (Sh.to_named(pspecs, mesh), Sh.to_named(bspecs, mesh))
    out_sh = Sh.to_named(out, mesh)
    return args, in_sh, out_sh


# ---------------------------------------------------------------------------
# Decode


def make_decode_state_shape(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract decode state (no allocation)."""
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    B, S = shape.global_batch, shape.seq_len

    def init(params, *frames):
        kw = {"enc_frames": frames[0]} if frames else {}
        return T.init_decode_state(params, cfg, B, S,
                                   jnp.dtype(cfg.param_dtype), **kw)

    extra = ()
    if cfg.is_encoder_decoder:
        extra = (jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                      jnp.float32),)
    return jax.eval_shape(init, params_shape, *extra), params_shape


def make_serve_step(cfg: ModelConfig):
    from repro import sharding as _Sh

    def serve_step(params, state, token):
        logits, state = T.decode_step(params, token, state, cfg)
        if _Sh.DECODE_OPT:
            # keep logits vocab-sharded; argmax reduces over the sharded
            # vocab dim (small collective) instead of all-gathering lm_head
            try:
                logits = jax.lax.with_sharding_constraint(
                    logits, P(None, None, "model"))
            except Exception:
                pass
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_token[:, None], state

    return serve_step


def decode_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    state_shape, params_shape = make_decode_state_shape(cfg, shape)
    token_shape = IS.decode_token_spec(cfg, shape)
    pspecs = Sh.param_specs(params_shape, cfg, mesh)
    sspecs = Sh.decode_state_specs(state_shape, cfg, mesh, shape)
    tspecs = Sh.batch_specs({"token": token_shape}, mesh)["token"]
    args = (params_shape, state_shape, token_shape)
    in_sh = (Sh.to_named(pspecs, mesh), Sh.to_named(sspecs, mesh),
             Sh.to_named(tspecs, mesh))
    out_sh = (Sh.to_named(tspecs, mesh), Sh.to_named(sspecs, mesh))
    return args, in_sh, out_sh


# ---------------------------------------------------------------------------
# FedSpace aggregation step (the paper's eq. 4 at datacenter scale)


def make_agg_step(cfg: ModelConfig, *, alpha: float = 0.5):
    from repro.core.aggregation import apply_aggregation

    def agg_step(params, update_stack, staleness):
        return apply_aggregation(params, update_stack, staleness,
                                 alpha=alpha)

    return agg_step


def make_agg_step_opt(cfg: ModelConfig, mesh: Mesh, *, alpha: float = 0.5,
                      reduce_dtype=jnp.bfloat16):
    """§Perf hillclimb C: hand-scheduled eq. 4 via shard_map.

    The buffer of M updates is sharded over 'data' (each host holds the
    updates it received); each shard computes its local staleness-weighted
    partial sum in f32, casts to bf16, and a single bf16 psum over 'data'
    combines — halving the collective bytes of the GSPMD baseline, which
    all-reduces the f32 delta. The final add to params stays f32."""
    from repro.core.staleness import staleness_compensation

    from repro.core.mesh import shard_map
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)

    def agg_step(params, update_stack, staleness):
        c = staleness_compensation(staleness, alpha)
        w = c / jnp.maximum(jnp.sum(c), 1e-12)
        pspecs = Sh.param_specs(params, cfg, mesh)

        def one(p, u, ps):
            uspec = P(dp, *tuple(ps))

            def body(pl, ul, wl):
                # keep the whole partial-sum path in bf16 so XLA's
                # excess-precision pass cannot promote the psum to f32
                # (M/16 = 6 local terms: bf16 accumulation error is ~0.4%
                # of the update, acceptable for eq. 4 — see EXPERIMENTS.md)
                local = jnp.tensordot(wl.astype(reduce_dtype),
                                      ul.astype(reduce_dtype), axes=1)
                delta = jax.lax.psum(local, dp)
                return (pl.astype(jnp.float32)
                        + delta.astype(jnp.float32)).astype(pl.dtype)

            return shard_map(
                body, mesh,
                in_specs=(ps, uspec, P(dp)),
                out_specs=ps)(p, u, w)

        return jax.tree.map(one, params, update_stack, pspecs,
                            is_leaf=lambda x: hasattr(x, "shape"))

    return agg_step


def agg_shardings(cfg: ModelConfig, mesh: Mesh, *, buffer_m: int = 96,
                  shard_buffer: bool = True):
    """Buffer of M satellite updates sharded along 'data' (each host stores
    the updates it received), model dims sharded like the params."""
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = Sh.param_specs(params_shape, cfg, mesh)
    upd_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((buffer_m,) + l.shape, l.dtype),
        params_shape)
    mspec = "data" if (shard_buffer and buffer_m % mesh.shape["data"] == 0) \
        else None
    uspecs = jax.tree.map(lambda ps: P(mspec, *tuple(ps)), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    stal_shape = jax.ShapeDtypeStruct((buffer_m,), jnp.int32)
    args = (params_shape, upd_shape, stal_shape)
    in_sh = (Sh.to_named(pspecs, mesh), Sh.to_named(uspecs, mesh),
             Sh.to_named(P(), mesh))
    out_sh = Sh.to_named(pspecs, mesh)
    return args, in_sh, out_sh


# ---------------------------------------------------------------------------
# Full FL round step: vmapped client local SGD (eq. 3) + eq. 4 aggregation
# — the paper's technique as ONE distributed datacenter step (used when the
# GS pod replays buffered client rounds, e.g. for utility-sample generation
# at scale).


def make_fl_round_step(cfg: ModelConfig, *, local_steps: int = 4,
                       client_lr: float = 0.05, alpha: float = 0.5,
                       q_chunk: int = 512):
    from repro.core.aggregation import apply_aggregation

    def client_update(params, batches):
        def body(p, batch):
            def loss_fn(p_):
                logits, aux = T.forward(p_, batch, cfg, q_chunk=q_chunk,
                                        remat=True)
                return T.lm_loss(logits, batch["labels"]) + 0.01 * aux
            g = jax.grad(loss_fn)(params if False else p)
            p = jax.tree.map(
                lambda w, g_: (w.astype(jnp.float32)
                               - client_lr * g_.astype(jnp.float32)
                               ).astype(w.dtype), p, g)
            return p, None

        final, _ = jax.lax.scan(body, params, batches)
        return jax.tree.map(lambda a, b: (a.astype(jnp.float32)
                                          - b.astype(jnp.float32)),
                            final, params)

    def fl_round_step(params, client_batches, staleness):
        """client_batches: pytree with leading (M, local_steps, B, ...)."""
        updates = jax.vmap(lambda b: client_update(params, b))(
            client_batches)
        return apply_aggregation(params, updates, staleness, alpha=alpha)

    return fl_round_step


def fl_round_shardings(cfg: ModelConfig, mesh: Mesh, *, buffer_m: int = 16,
                       local_steps: int = 4, batch: int = 8,
                       seq: int = 512):
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = Sh.param_specs(params_shape, cfg, mesh)
    cb = {
        "tokens": jax.ShapeDtypeStruct((buffer_m, local_steps, batch, seq),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((buffer_m, local_steps, batch, seq),
                                       jnp.int32),
    }
    mspec = "data" if buffer_m % mesh.shape["data"] == 0 else None
    cbspecs = jax.tree.map(lambda _: P(mspec), cb)
    stal = jax.ShapeDtypeStruct((buffer_m,), jnp.int32)
    args = (params_shape, cb, stal)
    in_sh = (Sh.to_named(pspecs, mesh), Sh.to_named(cbspecs, mesh),
             Sh.to_named(P(), mesh))
    out_sh = Sh.to_named(pspecs, mesh)
    return args, in_sh, out_sh


# ---------------------------------------------------------------------------
# Dispatcher used by dryrun / benchmarks


def build(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *,
          num_micro: int = None, q_chunk: int = 512, moe_groups: int = 1):
    """Returns (fn, abstract_args, in_shardings, out_shardings)."""
    if shape.kind == "agg":
        if moe_groups == -3:   # flag reuse: optimized shard_map agg step
            fn = make_agg_step_opt(cfg, mesh)
        else:
            fn = make_agg_step(cfg)
        args, i, o = agg_shardings(cfg, mesh,
                                   buffer_m=shape.global_batch)
        return fn, args, i, o
    if shape.kind == "flround":
        fn = make_fl_round_step(cfg)
        args, i, o = fl_round_shardings(cfg, mesh,
                                        buffer_m=shape.global_batch,
                                        seq=shape.seq_len)
        return fn, args, i, o
    if shape.kind == "train":
        nm = num_micro if num_micro is not None else \
            default_num_micro(cfg, shape, mesh)
        if moe_groups == -1:   # auto: one routing group per microbatch seq
            moe_groups = max(1, shape.global_batch // nm)
        # moe_groups == -2: expert-parallel shard_map path
        fn = make_train_step(cfg, mesh, num_micro=nm, q_chunk=q_chunk,
                             moe_groups=moe_groups)
        args, i, o = train_shardings(cfg, shape, mesh)
    elif shape.kind == "prefill":
        if moe_groups == -1:
            moe_groups = shape.global_batch
        fn = make_prefill_step(cfg, q_chunk=q_chunk, moe_groups=moe_groups)
        args, i, o = prefill_shardings(cfg, shape, mesh)
    else:
        fn = make_serve_step(cfg)
        args, i, o = decode_shardings(cfg, shape, mesh)
    return fn, args, i, o
