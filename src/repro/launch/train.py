"""Datacenter training launcher: train any zoo architecture with the pjit
train step on the available mesh (production meshes on real pods, host mesh
on CPU). Used by examples/satellite_fl_train.py for source-trajectory
pretraining and standalone for LM pretraining smoke runs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        --reduced --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.launch import steps as ST
from repro.launch.input_specs import train_batch_specs
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw_init


def synthetic_lm_batch(cfg, shape, step, seed=0):
    """Deterministic synthetic LM batch: structured token streams so loss
    actually decreases (next-token = current + class pattern)."""
    rng = np.random.default_rng(seed * 100_003 + step)
    specs = train_batch_specs(cfg, shape)
    B = shape.global_batch
    out = {}
    if "frames" in specs:
        out["frames"] = rng.normal(0, 1, specs["frames"].shape).astype(
            np.float32)
    if "image_embeds" in specs:
        out["image_embeds"] = rng.normal(
            0, 1, specs["image_embeds"].shape).astype(np.float32)
    st = specs["tokens"].shape[1]
    # periodic sequences with noise: learnable structure
    base = rng.integers(0, min(cfg.vocab_size, 97), (B, 1))
    pos = np.arange(st)[None, :]
    toks = (base + pos) % min(cfg.vocab_size, 97)
    flip = rng.random((B, st)) < 0.05
    toks = np.where(flip, rng.integers(0, cfg.vocab_size, (B, st)), toks)
    out["tokens"] = toks.astype(np.int32)
    labels = np.roll(toks, -1, axis=1)
    out["labels"] = labels.astype(np.int32)
    return {k: jnp.asarray(v) for k, v in out.items()}


def train(arch: str, *, reduced: bool = True, steps: int = 20,
          batch: int = 8, seq: int = 128, lr: float = 3e-4,
          num_micro: int = 1, mesh_kind: str = "host", log_every: int = 5):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("custom", seq_len=seq, global_batch=batch,
                        kind="train")
    mesh = {"host": make_host_mesh,
            "single": lambda: make_production_mesh(),
            "multi": lambda: make_production_mesh(multi_pod=True)
            }[mesh_kind]()
    with mesh:
        step_fn = jax.jit(ST.make_train_step(cfg, mesh,
                                             num_micro=num_micro,
                                             q_chunk=min(512, seq), lr=lr))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        history = []
        for s in range(steps):
            t0 = time.time()
            batch_data = synthetic_lm_batch(cfg, shape, s)
            params, opt, metrics = step_fn(params, opt, batch_data)
            loss = float(metrics["loss"])
            history.append(loss)
            if s % log_every == 0 or s == steps - 1:
                print(f"step {s:4d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.time() - t0:.2f}s)", flush=True)
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--num-micro", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", default=False)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    args = ap.parse_args()
    hist = train(args.arch, reduced=args.reduced, steps=args.steps,
                 batch=args.batch, seq=args.seq, lr=args.lr,
                 num_micro=args.num_micro, mesh_kind=args.mesh)
    print(f"loss: {hist[0]:.4f} -> {hist[-1]:.4f}")


if __name__ == "__main__":
    main()
