"""Functional optimizers (no external deps): SGD(+momentum) and AdamW.

State and updates are plain pytrees matching the parameter tree, so they
shard with the same PartitionSpec machinery (plus the ZeRO-1 'data'-axis
extension in repro.sharding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# SGD (the paper's satellite-side local optimizer, eq. 3)


def sgd_init(params, momentum: float = 0.0):
    if momentum == 0.0:
        return {"step": jnp.zeros((), jnp.int32)}
    return {"step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                               params)}


def sgd_update(grads, state, params, lr, momentum: float = 0.0,
               weight_decay: float = 0.0, trainable_mask=None):
    if trainable_mask is not None:
        grads = jax.tree.map(lambda g, m: g * m, grads, trainable_mask)
    if momentum == 0.0:
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        new_state = {"step": state["step"] + 1}
    else:
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                          state["mu"], grads)
        updates = jax.tree.map(lambda m: -lr * m, mu)
        new_state = {"step": state["step"] + 1, "mu": mu}
    if weight_decay:
        updates = jax.tree.map(
            lambda u, p: u - lr * weight_decay * p.astype(jnp.float32),
            updates, params)
    return updates, new_state


# ---------------------------------------------------------------------------
# AdamW (datacenter-side training)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(grads, state, params, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                     state["m"], grads)
    v = jax.tree.map(
        lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    updates = jax.tree.map(
        lambda m_, v_, p: -lr * ((m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
                                 + weight_decay * p.astype(jnp.float32)),
        m, v, params)
    return updates, {"step": step, "m": m, "v": v}


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)
