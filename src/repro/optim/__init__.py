from repro.optim.optimizers import (adamw_init, adamw_update, sgd_init,
                                    sgd_update, apply_updates, global_norm,
                                    clip_by_global_norm)
from repro.optim.schedule import cosine_schedule, constant_schedule
