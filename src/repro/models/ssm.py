"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation inside fixed-size chunks + a linear recurrence across chunk
states. Decode carries the (H, N, P) recurrent state and a causal-conv tail.

Dims: B batch, Sq seq, D d_model, Di = expand·D inner, P = head_dim,
H = Di/P heads, N = ssm_state_dim. B/C projections are shared across heads
(ngroups = 1, as in the 370M model).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


class SSMState(NamedTuple):
    s: jnp.ndarray       # (B, H, N, P) recurrent state
    conv: jnp.ndarray    # (B, W-1, Di + 2N) conv tail


def _dims(cfg: ModelConfig):
    di = cfg.ssm_expand * cfg.d_model
    p = cfg.ssm_head_dim
    h = di // p
    n = cfg.ssm_state_dim
    return di, p, h, n


def ssm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di, p, h, n = _dims(cfg)
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    return {
        "norm": L.rmsnorm_init(d, dt),
        # order of proj outputs: [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": L.dense_init(ks[0], d, 2 * di + 2 * n + h, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch),
                                     jnp.float32) * 0.2).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.zeros((h,), jnp.float32),           # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),    # softplus ≈ 0.12
        "out_norm": L.rmsnorm_init(di, dt),
        "out_proj": L.dense_init(ks[2], di, d, dt),
    }


def _split_proj(cfg, proj):
    di, p, h, n = _dims(cfg)
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over the sequence axis. xbc: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return out + b


def _segsum_decay(dta):
    """dta: (..., Q, H) per-step log-decay. Returns L: (..., H, Q, Q) with
    L[t,s] = exp(sum_{s<τ<=t} dta_τ) for s<=t else 0."""
    cs = jnp.cumsum(dta, axis=-2)                          # (..., Q, H)
    diff = cs[..., :, None, :] - cs[..., None, :, :]       # (..., t, s, H)
    Q = dta.shape[-2]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(mask[..., None], diff, -jnp.inf)
    return jnp.exp(diff)                                   # (..., t, s, H)


def ssm_apply(params, x, cfg: ModelConfig):
    """Full-sequence SSD. x: (B, S, D) -> (B, S, D) with residual."""
    Bsz, S, D = x.shape
    di, p, h, n = _dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    while S % Q:          # largest divisor of S not exceeding ssm_chunk
        Q -= 1
    nc = S // Q

    hin = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    proj = hin @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"],
                                   params["conv_b"]).astype(jnp.float32)
                      ).astype(x.dtype)
    xs = xbc[..., :di].reshape(Bsz, S, h, p)
    Bm = xbc[..., di:di + n]
    Cm = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                          # (h,)
    dta = dt * A                                           # (B,S,h) log decay

    # chunk views
    xc = xs.reshape(Bsz, nc, Q, h, p)
    bc = Bm.reshape(Bsz, nc, Q, n)
    cc = Cm.reshape(Bsz, nc, Q, n)
    dtc = dt.reshape(Bsz, nc, Q, h)
    dtac = dta.reshape(Bsz, nc, Q, h)

    dtx = xc * dtc[..., None].astype(xc.dtype)             # (B,nc,Q,h,p)

    # --- intra-chunk (diagonal blocks) ---
    Lm = _segsum_decay(dtac)                               # (B,nc,t,s,h)
    cb = jnp.einsum("bctn,bcsn->bcts", cc, bc,
                    preferred_element_type=jnp.float32)    # (B,nc,t,s)
    scores = (cb[..., None] * Lm).astype(xc.dtype)         # (B,nc,t,s,h)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", scores, dtx)

    # --- chunk states and inter-chunk recurrence ---
    cum = jnp.cumsum(dtac, axis=2)                         # (B,nc,Q,h)
    total = cum[:, :, -1, :]                               # (B,nc,h)
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)     # (B,nc,Q,h)
    states = jnp.einsum("bcsh,bcsn,bcshp->bchnp",
                        decay_to_end.astype(xc.dtype), bc.astype(xc.dtype),
                        dtx)                               # (B,nc,h,n,p)

    def scan_body(s_prev, inp):
        st, tot = inp                                      # (B,h,n,p), (B,h)
        s_new = s_prev * jnp.exp(tot)[..., None, None].astype(st.dtype) + st
        return s_new, s_prev

    s0 = jnp.zeros((Bsz, h, n, p), xc.dtype)
    _, s_prevs = jax.lax.scan(
        scan_body, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                  # (B,nc,h,n,p)

    y_inter = jnp.einsum("bctn,bchnp->bcthp", cc.astype(xc.dtype), s_prevs)
    y_inter = y_inter * jnp.exp(cum)[..., None].astype(xc.dtype)

    y = (y_intra + y_inter).reshape(Bsz, S, h, p)
    y = y + xs * params["D"].astype(xs.dtype)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    y = L.rmsnorm(params["out_norm"],
                  y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  cfg.norm_eps)
    return x + y @ params["out_proj"]


# ---------------------------------------------------------------------------
# Decode


def ssm_init_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    di, p, h, n = _dims(cfg)
    return SSMState(
        s=jnp.zeros((batch, h, n, p), dtype),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, di + 2 * n), dtype),
    )


def ssm_decode(params, x, state: SSMState, cfg: ModelConfig):
    """x: (B, 1, D) -> (y, new_state)."""
    Bsz = x.shape[0]
    di, p, h, n = _dims(cfg)
    hin = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    proj = (hin @ params["in_proj"])[:, 0]                 # (B, ·)
    z, xbc, dt_raw = _split_proj(cfg, proj)
    window = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) \
        + params["conv_b"]
    xbc_t = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xt = xbc_t[:, :di].reshape(Bsz, h, p)
    Bt = xbc_t[:, di:di + n]
    Ct = xbc_t[:, di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                                # (B,h)
    upd = jnp.einsum("bn,bhp->bhnp", Bt, xt * dt[..., None].astype(xt.dtype))
    s_new = state.s * decay[..., None, None].astype(state.s.dtype) + upd
    y = jnp.einsum("bn,bhnp->bhp", Ct, s_new) \
        + xt * params["D"].astype(xt.dtype)[None, :, None]
    y = y.reshape(Bsz, di)
    y = L.rmsnorm(params["out_norm"],
                  y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                  cfg.norm_eps)
    out = x + (y @ params["out_proj"])[:, None, :]
    return out, SSMState(s=s_new, conv=window[:, 1:, :])


def ssm_reference(params, x, cfg: ModelConfig):
    """Sequential recurrence oracle for tests (O(S) python-free scan)."""
    Bsz, S, D = x.shape
    state = ssm_init_state(cfg, Bsz, x.dtype)

    def body(st, xt):
        y, st2 = ssm_decode(params, xt[:, None, :], st, cfg)
        return st2, y[:, 0]

    _, ys = jax.lax.scan(body, state, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(ys, 0, 1)
