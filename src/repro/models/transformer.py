"""Composable decoder stack for all assigned architectures.

A model is a sequence of *stages* (``StageSpec``); each stage is a pattern of
block kinds repeated N times and executed with ``jax.lax.scan`` over stacked
parameters (keeps HLO size ~O(pattern), not O(num_layers), which matters at
48 layers x 512 virtual devices in the dry-run).

Block kinds:
  global / local  -> attention (+ dense or MoE FFN)
  enc             -> bidirectional attention (+ FFN)   [whisper encoder]
  cross           -> causal self-attn + cross-attn + FFN [whisper decoder]
  recurrent       -> RG-LRU (+ FFN)
  ssm             -> Mamba-2 SSD (self-contained, no FFN)
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, StageSpec
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S

VISION_DIM = 1024  # stub CLIP/SigLIP patch-embedding width


# ---------------------------------------------------------------------------
# Block init / apply / decode


def _ffn_init(key, cfg: ModelConfig):
    if cfg.num_experts:
        return {"moe": M.moe_init(key, cfg)}
    return {"norm": L.rmsnorm_init(cfg.d_model, L.dtype_of(cfg)),
            "mlp": L.mlp_init(key, cfg)}


def _ffn_apply(params, x, cfg: ModelConfig, moe_groups: int = 1,
               moe_ep=None):
    if cfg.num_experts:
        if moe_ep is not None:
            mesh, data_axes = moe_ep
            if cfg.num_experts % mesh.shape["model"] == 0:
                return M.moe_apply_ep(params["moe"], x, cfg, mesh,
                                      data_axes=data_axes)
        return M.moe_apply(params["moe"], x, cfg, groups=moe_groups)
    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    return x + L.mlp_apply(params["mlp"], h, cfg.mlp_act), 0.0


def block_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 3)
    if kind == "ssm":
        return {"ssm": S.ssm_init(ks[0], cfg)}
    if kind == "recurrent":
        return {"rec": R.rglru_init(ks[0], cfg), "ffn": _ffn_init(ks[1], cfg)}
    if kind == "cross":
        return {"attn": A.attention_init(ks[0], cfg, "global"),
                "xattn": A.attention_init(ks[1], cfg, "global"),
                "ffn": _ffn_init(ks[2], cfg)}
    return {"attn": A.attention_init(ks[0], cfg, kind),
            "ffn": _ffn_init(ks[1], cfg)}


def block_apply(params, x, cfg: ModelConfig, kind: str, *,
                positions=None, enc_out=None, q_chunk=512, moe_groups=1,
                moe_ep=None):
    aux = 0.0
    if kind == "ssm":
        return S.ssm_apply(params["ssm"], x, cfg), aux
    if kind == "recurrent":
        x = R.rglru_apply(params["rec"], x, cfg)
    elif kind == "cross":
        x = A.attention_apply(params["attn"], x, cfg, "global",
                              q_chunk=q_chunk, positions=positions)
        k, v = A.cross_kv(params["xattn"], enc_out, cfg)
        x = A.attention_apply(params["xattn"], x, cfg, "global",
                              q_chunk=q_chunk, positions=positions,
                              kv_override=(k, v, False))
    else:
        x = A.attention_apply(params["attn"], x, cfg, kind,
                              q_chunk=q_chunk, positions=positions)
    x, aux = _ffn_apply(params["ffn"], x, cfg, moe_groups, moe_ep)
    return x, aux


def block_init_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                     dtype, enc_out=None, params=None):
    if kind == "ssm":
        return S.ssm_init_state(cfg, batch, dtype)
    if kind == "recurrent":
        return R.rglru_init_state(params and params.get("rec"), cfg, batch,
                                  dtype)
    if kind == "cross":
        ck, cv = A.cross_kv(params["xattn"], enc_out, cfg)
        return {"self": A.init_cache(cfg, "global", batch, seq_len, dtype),
                "cross_k": ck, "cross_v": cv}
    return A.init_cache(cfg, kind, batch, seq_len, dtype)


def block_decode(params, x, cache, index, cfg: ModelConfig, kind: str):
    if kind == "ssm":
        return S.ssm_decode(params["ssm"], x, cache, cfg)
    if kind == "recurrent":
        x, cache = R.rglru_decode(params["rec"], x, cache, cfg)
    elif kind == "cross":
        x, self_c = A.attention_decode(params["attn"], x, cache["self"],
                                       index, cfg, "global")
        x, _ = A.attention_decode(
            params["xattn"], x, None, index, cfg, "global",
            kv_override=(cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, self=self_c)
    else:
        x, cache = A.attention_decode(params["attn"], x, cache, index, cfg,
                                      kind)
    x, _ = _ffn_apply(params["ffn"], x, cfg)
    return x, cache


# ---------------------------------------------------------------------------
# Stage (scanned repeats of a pattern)


def stage_init(key, cfg: ModelConfig, spec: StageSpec):
    def one_rep(k):
        kk = jax.random.split(k, len(spec.pattern))
        return {f"pos{j}": block_init(kk[j], cfg, kind)
                for j, kind in enumerate(spec.pattern)}
    keys = jax.random.split(key, spec.repeats)
    return jax.vmap(one_rep)(keys)


def stage_apply(stage_params, x, cfg: ModelConfig, spec: StageSpec, *,
                positions=None, enc_out=None, q_chunk=512, remat=True,
                moe_groups=1, moe_ep=None):
    def body(carry, rep_params):
        h, aux = carry
        for j, kind in enumerate(spec.pattern):
            h, a = block_apply(rep_params[f"pos{j}"], h, cfg, kind,
                               positions=positions, enc_out=enc_out,
                               q_chunk=q_chunk, moe_groups=moe_groups,
                               moe_ep=moe_ep)
            aux = aux + a
        return (h, aux), None

    fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)), stage_params)
    return x, aux


def stage_init_cache(cfg: ModelConfig, spec: StageSpec, batch, seq_len, dtype,
                     enc_out=None, stage_params=None):
    def one_rep(rep_params):
        return {f"pos{j}": block_init_cache(
            cfg, kind, batch, seq_len, dtype, enc_out=enc_out,
            params=None if rep_params is None else rep_params[f"pos{j}"])
            for j, kind in enumerate(spec.pattern)}
    if stage_params is None:
        caches = [one_rep(None) for _ in range(spec.repeats)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *caches) \
            if spec.repeats > 1 else jax.tree.map(
                lambda v: v[None], caches[0])
    return jax.vmap(one_rep)(stage_params)


def stage_decode(stage_params, caches, x, index, cfg: ModelConfig,
                 spec: StageSpec):
    def body(h, inp):
        rep_params, rep_cache = inp
        new_cache = {}
        for j, kind in enumerate(spec.pattern):
            h, nc = block_decode(rep_params[f"pos{j}"], h,
                                 rep_cache[f"pos{j}"], index, cfg, kind)
            new_cache[f"pos{j}"] = nc
        return h, new_cache

    x, new_caches = jax.lax.scan(body, x, (stage_params, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# Full model


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"emb": L.embedding_init(ks[0], cfg)}
    p["stages"] = [stage_init(jax.random.fold_in(ks[1], i), cfg, spec)
                   for i, spec in enumerate(cfg.stages)]
    p["final_norm"] = L.rmsnorm_init(cfg.d_model, L.dtype_of(cfg))
    if cfg.is_encoder_decoder:
        enc_spec = StageSpec(("enc",), cfg.encoder_layers)
        p["enc_stage"] = stage_init(ks[2], cfg, enc_spec)
        p["enc_norm"] = L.rmsnorm_init(cfg.d_model, L.dtype_of(cfg))
    if cfg.frontend == "vision":
        p["vis_proj"] = L.dense_init(ks[3], VISION_DIM, cfg.d_model,
                                     L.dtype_of(cfg))
    return p


def _encode(params, frames, cfg: ModelConfig, q_chunk):
    enc_spec = StageSpec(("enc",), cfg.encoder_layers)
    h, _ = stage_apply(params["enc_stage"], frames, cfg, enc_spec,
                       q_chunk=q_chunk)
    return L.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig, *, q_chunk=512, remat=True,
            moe_groups=1, moe_ep=None):
    """Full-sequence forward. Returns (logits, aux_loss).

    batch keys: tokens [+ image_embeds | frames].
    """
    tokens = batch["tokens"]
    positions = None
    enc_out = None
    if cfg.frontend == "vision":
        img = (batch["image_embeds"] @ params["vis_proj"]).astype(
            L.dtype_of(cfg))
        txt = L.embed(params["emb"], tokens, cfg)
        x = jnp.concatenate([img, txt], axis=1)
    elif cfg.is_encoder_decoder:
        enc_out = _encode(params, batch["frames"].astype(L.dtype_of(cfg)),
                          cfg, q_chunk)
        x = L.embed(params["emb"], tokens, cfg)
    else:
        x = L.embed(params["emb"], tokens, cfg)

    aux = jnp.float32(0.0)
    for spec, sp in zip(cfg.stages, params["stages"]):
        x, a = stage_apply(sp, x, cfg, spec, positions=positions,
                           enc_out=enc_out, q_chunk=q_chunk, remat=remat,
                           moe_groups=moe_groups, moe_ep=moe_ep)
        aux = aux + a
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.frontend == "vision":
        x = x[:, batch["image_embeds"].shape[1]:]   # logits on text positions
    logits = L.unembed(params["emb"], x, cfg)
    return logits, aux


def init_decode_state(params, cfg: ModelConfig, batch: int, seq_len: int,
                      dtype, enc_frames=None):
    """Decode state: per-stage caches + running index."""
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encode(params, enc_frames.astype(L.dtype_of(cfg)), cfg,
                          512)
    caches = [stage_init_cache(cfg, spec, batch, seq_len, dtype,
                               enc_out=enc_out, stage_params=sp)
              for spec, sp in zip(cfg.stages, params["stages"])]
    return {"caches": caches, "index": jnp.zeros((), jnp.int32)}


def decode_step(params, token, state, cfg: ModelConfig):
    """token: (B, 1) int32. Returns (logits (B,1,V), new_state)."""
    x = L.embed(params["emb"], token, cfg)
    index = state["index"]
    new_caches = []
    for spec, sp, cache in zip(cfg.stages, params["stages"],
                               state["caches"]):
        x, nc = stage_decode(sp, cache, x, index, cfg, spec)
        new_caches.append(nc)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["emb"], x, cfg)
    return logits, {"caches": new_caches, "index": index + 1}


# ---------------------------------------------------------------------------
# Loss


def lm_loss(logits, labels, mask=None):
    """Cross-entropy in f32 with optional validity mask."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
