"""Compact DenseNet-style CNN — the paper's own FL model (DenseNet-161 on
fMoW, batch-norm replaced by group-norm per Hsieh et al. 2020; we implement
the same architecture family at reduced width — see DESIGN.md §7).

Used by the FL experiments (62-class image classification). Supports a
``frozen_blocks`` prefix mirroring the paper's transfer-learning setup (the
FL optimizer masks those gradients).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NUM_GROUPS = 8


def _conv_init(key, kh, kw, cin, cout):
    scale = (kh * kw * cin) ** -0.5
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _groupnorm(params, x, eps=1e-5):
    B, H, W, C = x.shape
    g = min(NUM_GROUPS, C)
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mu) * jax.lax.rsqrt(var + eps)).reshape(B, H, W, C)
    return xn * params["scale"] + params["bias"]


def _gn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def densenet_init(key, *, num_classes=62, growth=12, blocks=(4, 4, 4, 4),
                  stem=24, in_channels=3):
    ks = iter(jax.random.split(key, 4 + sum(blocks) * 2 + len(blocks) * 2))
    p = {"stem": _conv_init(next(ks), 3, 3, in_channels, stem)}
    c = stem
    p["blocks"] = []
    for bi, n in enumerate(blocks):
        layers = []
        for _ in range(n):
            layers.append({
                "gn": _gn_init(c),
                "conv": _conv_init(next(ks), 3, 3, c, growth),
            })
            c += growth
        blk = {"layers": layers}
        if bi != len(blocks) - 1:
            cout = c // 2
            blk["trans"] = {"gn": _gn_init(c),
                            "conv": _conv_init(next(ks), 1, 1, c, cout)}
            c = cout
        p["blocks"].append(blk)
    p["head_gn"] = _gn_init(c)
    p["head"] = jax.random.normal(next(ks), (c, num_classes),
                                  jnp.float32) * c ** -0.5
    return p


def densenet_apply(params, x):
    """x: (B, H, W, C) float -> logits (B, num_classes)."""
    h = _conv(x, params["stem"])
    for blk in params["blocks"]:
        for lyr in blk["layers"]:
            y = jax.nn.relu(_groupnorm(lyr["gn"], h))
            y = _conv(y, lyr["conv"])
            h = jnp.concatenate([h, y], axis=-1)
        if "trans" in blk:
            h = jax.nn.relu(_groupnorm(blk["trans"]["gn"], h))
            h = _conv(h, blk["trans"]["conv"])
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID") / 4.0
    h = jax.nn.relu(_groupnorm(params["head_gn"], h))
    h = jnp.mean(h, axis=(1, 2))
    return h @ params["head"]


def frozen_mask(params, frozen_blocks: int):
    """1.0 for trainable leaves, 0.0 for frozen (stem + first N blocks) —
    mirrors the paper's 'freeze the lower 3 dense blocks'."""
    mask = jax.tree.map(lambda _: 1.0, params)
    if frozen_blocks <= 0:
        return mask
    mask["stem"] = jax.tree.map(lambda _: 0.0, mask["stem"])
    for bi in range(min(frozen_blocks, len(params["blocks"]))):
        mask["blocks"][bi] = jax.tree.map(lambda _: 0.0, mask["blocks"][bi])
    return mask
