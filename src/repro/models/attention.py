"""Attention blocks: GQA with RoPE, optional qk-norm, global (causal) and
local (sliding-window) variants; chunked (flash-style, online-softmax-free —
per-q-chunk full softmax) training path and single-token decode against a KV
cache.

Layouts:
  activations  x        : (B, S, D)
  q            q        : (B, S, H, hd)
  kv           k, v     : (B, T, K, hd)      K = num_kv_heads
  kv cache     (B, T, K, hd) with a scalar `index` for the write position;
               local layers keep T = window (ring buffer).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

NEG_INF = -1e30

# When True, the per-q-chunk attention body is jax.checkpoint-ed so the
# backward pass recomputes score panels instead of saving a (n_chunks, bq,
# T) f32 stack per layer — the dominant HBM term of the train shapes
# (EXPERIMENTS.md §Perf). Set via remat_attention_chunks(); default False
# keeps the paper-faithful baseline lowering.
_REMAT_CHUNKS = False


class remat_attention_chunks:
    def __init__(self, enable: bool = True):
        self.enable = enable

    def __enter__(self):
        global _REMAT_CHUNKS
        self._old = _REMAT_CHUNKS
        _REMAT_CHUNKS = self.enable

    def __exit__(self, *a):
        global _REMAT_CHUNKS
        _REMAT_CHUNKS = self._old


def _pick_chunk(S: int, q_chunk: int) -> int:
    """Largest divisor of S that is <= q_chunk."""
    qc = min(q_chunk, S)
    while S % qc:
        qc -= 1
    return qc


class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, T, K, hd)
    v: jnp.ndarray       # (B, T, K, hd)


def attention_init(key, cfg: ModelConfig, kind: str):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "norm": L.rmsnorm_init(d, dt),
        "wq": L.dense_init(ks[0], d, H * hd, dt),
        "wk": L.dense_init(ks[1], d, K * hd, dt),
        "wv": L.dense_init(ks[2], d, K * hd, dt),
        "wo": L.dense_init(ks[3], H * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(hd, dt)
        p["k_norm"] = L.rmsnorm_init(hd, dt)
    return p


def _project_qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, K, hd)
    v = (x @ params["wv"]).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q: (B,Sq,K,G,hd)  k,v: (B,Tk,K,hd)  mask: (B or 1, Sq, Tk) bool."""
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v)


def _attend_full(q, k, v, cfg: ModelConfig, q_chunk: int, causal: bool = True):
    """Chunked causal attention over the full sequence (global layers)."""
    B, S, H, hd = q.shape
    K = cfg.num_kv_heads
    G = H // K
    scale = hd ** -0.5
    q = q.reshape(B, S, K, G, hd)
    qc = _pick_chunk(S, q_chunk)
    n = S // qc

    T = k.shape[1]

    def body(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
        qpos = i * qc + jnp.arange(qc)
        kpos = jnp.arange(T)
        mask = (kpos[None, None, :] <= qpos[None, :, None]) if causal else \
            jnp.ones((1, qc, T), bool)
        o = _sdpa(qi, k, v, mask, scale)
        return carry, o

    fn = jax.checkpoint(body) if _REMAT_CHUNKS else body
    _, out = jax.lax.scan(fn, 0, jnp.arange(n))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)
    return out


def _attend_local(q, k, v, cfg: ModelConfig, q_chunk: int):
    """Sliding-window causal attention; each q chunk only sees a
    (window + q_chunk)-wide kv slice — sub-quadratic in S."""
    B, S, H, hd = q.shape
    K = cfg.num_kv_heads
    G = H // K
    W = cfg.window_size
    scale = hd ** -0.5
    if S <= W:  # window covers everything
        return _attend_full(q, k, v, cfg, q_chunk)
    q = q.reshape(B, S, K, G, hd)
    qc = _pick_chunk(S, q_chunk)
    n = S // qc
    # Pre-pad kv in front so every slice is in-bounds.
    kp = jnp.pad(k, ((0, 0), (W, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (W, 0), (0, 0), (0, 0)))

    def body(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * qc, qc, axis=1)
        ki = jax.lax.dynamic_slice_in_dim(kp, i * qc, W + qc, axis=1)
        vi = jax.lax.dynamic_slice_in_dim(vp, i * qc, W + qc, axis=1)
        qpos = i * qc + jnp.arange(qc)
        kpos = i * qc - W + jnp.arange(W + qc)
        diff = qpos[:, None] - kpos[None, :]
        mask = ((diff >= 0) & (diff < W) & (kpos[None, :] >= 0))[None]
        o = _sdpa(qi, ki, vi, mask, scale)
        return carry, o

    fn = jax.checkpoint(body) if _REMAT_CHUNKS else body
    _, out = jax.lax.scan(fn, 0, jnp.arange(n))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def attention_apply(params, x, cfg: ModelConfig, kind: str,
                    q_chunk: int = 512, positions=None, kv_override=None):
    """Full-sequence (train/prefill) attention block with pre-norm+residual.

    kv_override: (k, v, kv_positions, causal) — used by cross-attention.
    """
    B, S, _ = x.shape
    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    if kv_override is None:
        q, k, v = _project_qkv(params, h, cfg, positions)
        if kind == "local":
            o = _attend_local(q, k, v, cfg, q_chunk)
        else:
            causal = kind != "enc"
            o = _attend_full(q, k, v, cfg, q_chunk, causal=causal)
    else:
        k, v, causal = kv_override
        hd = cfg.resolved_head_dim
        q = (h @ params["wq"]).reshape(B, S, cfg.num_heads, hd)
        if cfg.qk_norm:
            q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        o = _attend_full(q, k, v, cfg, q_chunk, causal=causal)
    o = o.reshape(B, S, -1) @ params["wo"]
    return x + o


def cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output."""
    B, T, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = (enc_out @ params["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
    v = (enc_out @ params["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        k = L.rmsnorm(params["k_norm"], k, cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)


def init_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int, dtype):
    T = min(cfg.window_size, seq_len) if kind == "local" else seq_len
    hd = cfg.resolved_head_dim
    shape = (batch, T, cfg.num_kv_heads, hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def attention_decode(params, x, cache: KVCache, index, cfg: ModelConfig,
                     kind: str, kv_override=None):
    """x: (B, 1, D); index: scalar int32 — number of tokens already in cache.

    Returns (y, new_cache). Local layers treat the cache as a ring buffer.
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    G = H // K
    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    pos = jnp.broadcast_to(index[None] if index.ndim == 0 else index, (B, 1)) \
        if not isinstance(index, int) else jnp.full((B, 1), index)
    if kv_override is None:
        q, k_new, v_new = _project_qkv(params, h, cfg, pos)
        T = cache.k.shape[1]
        slot = (index % T).astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
        cache = KVCache(ck, cv)
        # validity: ring buffer slots written so far, within window for local
        tpos = jnp.arange(T)
        n_written = jnp.minimum(index + 1, T)
        if kind == "local":
            valid = (tpos < n_written)
        else:
            valid = tpos <= index
        mask = jnp.broadcast_to(valid[None, None, :], (1, 1, T))
        o = _sdpa(q.reshape(B, 1, K, G, hd), ck, cv, mask, hd ** -0.5)
    else:
        k, v = kv_override
        q = (h @ params["wq"]).reshape(B, 1, H, hd)
        if cfg.qk_norm:
            q = L.rmsnorm(params["q_norm"], q, cfg.norm_eps)
        T = k.shape[1]
        mask = jnp.ones((1, 1, T), bool)
        o = _sdpa(q.reshape(B, 1, K, G, hd), k, v, mask, hd ** -0.5)
    y = o.reshape(B, 1, H * hd) @ params["wo"]
    return x + y, cache
