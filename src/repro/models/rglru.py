"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Block: norm -> {x-branch: linear -> causal conv -> RG-LRU} * gelu(gate-branch)
-> out projection, with residual.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a h_in + b_a)            (recurrence gate)
    i_t = sigmoid(W_x h_in + b_x)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training uses jax.lax.associative_scan over the sequence; decode carries
(h, conv tail).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

_C = 8.0
_CONV_W = 4


class LRUState(NamedTuple):
    h: jnp.ndarray       # (B, lru_width)
    conv: jnp.ndarray    # (B, CONV_W-1, lru_width)


def rglru_init(key, cfg: ModelConfig):
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    # Lambda init so that a ~ U(0.9, 0.999) at r = 0.5
    lam = jnp.linspace(0.7, 2.5, w).astype(jnp.float32)
    return {
        "norm": L.rmsnorm_init(d, dt),
        "w_x": L.dense_init(ks[0], d, w, dt),
        "w_gate": L.dense_init(ks[1], d, w, dt),
        "conv_w": (jax.random.normal(ks[2], (_CONV_W, w), jnp.float32)
                   * 0.2).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": L.dense_init(ks[3], w, w, dt),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": L.dense_init(ks[4], w, w, dt),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam,
        "out_proj": L.dense_init(ks[5], w, d, dt),
    }


def _gates(params, xc):
    r = jax.nn.sigmoid((xc @ params["w_a"]).astype(jnp.float32)
                       + params["b_a"])
    i = jax.nn.sigmoid((xc @ params["w_i"]).astype(jnp.float32)
                       + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * xc.astype(jnp.float32)   # (a_t, u_t): h = a h- + u


def rglru_apply(params, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D) with residual."""
    B, S, D = x.shape
    hin = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    xb = hin @ params["w_x"]
    gate = jax.nn.gelu((hin @ params["w_gate"]).astype(jnp.float32))
    xp = jnp.pad(xb, ((0, 0), (_CONV_W - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + S, :] * params["conv_w"][i] for i in range(_CONV_W))
    xc = xc + params["conv_b"]
    a, u = _gates(params, xc)                     # (B,S,W) f32

    def combine(e1, e2):
        a1, u1 = e1
        a2, u2 = e2
        return a1 * a2, a2 * u1 + u2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    y = (h * gate).astype(x.dtype)
    return x + y @ params["out_proj"]


def rglru_init_state(params, cfg: ModelConfig, batch: int, dtype) -> LRUState:
    w = cfg.lru_width or cfg.d_model
    return LRUState(h=jnp.zeros((batch, w), jnp.float32),
                    conv=jnp.zeros((batch, _CONV_W - 1, w), dtype))


def rglru_decode(params, x, state: LRUState, cfg: ModelConfig):
    """x: (B, 1, D) -> (y, new_state)."""
    B = x.shape[0]
    hin = L.rmsnorm(params["norm"], x, cfg.norm_eps)[:, 0]
    xb = hin @ params["w_x"]
    gate = jax.nn.gelu((hin @ params["w_gate"]).astype(jnp.float32))
    window = jnp.concatenate([state.conv, xb[:, None, :]], axis=1)
    xc = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    a, u = _gates(params, xc)
    h_new = a * state.h + u
    y = (h_new * gate).astype(x.dtype)
    out = x + (y @ params["out_proj"])[:, None, :]
    return out, LRUState(h=h_new, conv=window[:, 1:, :])


def rglru_reference(params, x, cfg: ModelConfig):
    """Step-by-step oracle for tests."""
    B, S, D = x.shape
    st = rglru_init_state(params, cfg, B, x.dtype)

    def body(s, xt):
        y, s2 = rglru_decode(params, xt[:, None, :], s, cfg)
        return s2, y[:, 0]

    _, ys = jax.lax.scan(body, st, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(ys, 0, 1)
