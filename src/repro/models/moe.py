"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design note (roofline honesty): GShard-style one-hot dispatch einsums turn
token routing into O(T·E·C·D) matmul FLOPs, which would swamp the compiled
FLOP count with bookkeeping. Here routing is pure data movement
(argsort + scatter/gather — zero FLOPs in HLO cost analysis) and the expert
computation is a grouped einsum over an (E, C, D) buffer, so HLO_FLOPs ≈
active-expert FLOPs (top-k · tokens), matching MODEL_FLOPS = 6·N_active·D.

Token overflow beyond per-expert capacity C = ceil(k·T/E · cf) is dropped
(standard capacity-factor semantics); tests check the no-drop regime matches
a dense reference exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def moe_init(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)

    def einit(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    return {
        "norm": L.rmsnorm_init(d, dt),
        "router": einit(ks[0], (d, e), d ** -0.5),
        "w_gate": einit(ks[1], (e, d, f), d ** -0.5),
        "w_up": einit(ks[2], (e, d, f), d ** -0.5),
        "w_down": einit(ks[3], (e, f, d), f ** -0.5),
    }


def _constrain(x, spec):
    """Best-effort sharding constraint: applies when a mesh with the named
    axes is in scope (pjit paths); a no-op on plain CPU tests."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(cfg.experts_per_token * num_tokens / cfg.num_experts
            * cfg.moe_capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_apply(params, x, cfg: ModelConfig, *, groups: int = 1):
    """x: (B, S, D) -> (B, S, D) with residual; also returns aux loss.

    groups > 1 (beyond-paper §Perf optimization): tokens are split into
    `groups` independent routing groups (aligned with the data-parallel
    axis by the caller) so the argsort/scatter dispatch stays local to a
    shard — under GSPMD the global-token dispatch otherwise degenerates
    into replicated compute + giant all-reduces (see EXPERIMENTS.md §Perf).
    Routing quality is unchanged in expectation; capacity is enforced per
    group instead of globally.
    """
    if groups > 1:
        return _moe_apply_grouped(params, x, cfg, groups)
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    xt = h.reshape(B * S, D)
    T = B * S
    C = _capacity(cfg, T)

    logits = (xt @ params["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Flatten the T*k assignments and sort them by expert id.
    flat_e = expert_idx.reshape(-1)                              # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)                        # (T*k,)
    flat_g = gate_vals.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * k, dtype=jnp.int32) - offsets[se]
    keep = rank < C
    # overflow slots get an out-of-bounds rank so mode="drop" discards them
    # (clamping to 0 would overwrite a real token's slot)
    rank_s = jnp.where(keep, rank, C)
    rank_c = jnp.where(keep, rank, 0)   # clamped form for the gather side

    # Scatter tokens into the (E, C, D) expert buffer (pure data movement).
    buf = jnp.zeros((E, C, D), xt.dtype).at[se, rank_s].set(
        xt[st], mode="drop")

    # Grouped expert FFN — the only FLOP-bearing ops in the block.
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", act, params["w_down"])        # (E, C, D)

    # Combine back to token order with gate weighting.
    per_assign = y[se, rank_c] * (sg * keep).astype(y.dtype)[:, None]
    out = jnp.zeros((T, D), y.dtype).at[st].add(per_assign)

    # Load-balancing auxiliary loss (Switch-style).
    frac_tokens = counts.astype(jnp.float32) / (T * k)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    return x + out.reshape(B, S, D), aux


def _moe_apply_grouped(params, x, cfg: ModelConfig, groups: int):
    """Group-local dispatch (§Perf optimization, see moe_apply docstring).

    All routing bookkeeping (top-k, rank-in-expert, scatter/gather) carries
    an explicit leading group axis constrained to the 'data' mesh axis, so
    GSPMD keeps it local to a shard; only the expert einsum touches the
    'model'-sharded expert weights. Capacity is per group."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    assert T % groups == 0, (T, groups)
    G, Tg = groups, T // groups
    C = _capacity(cfg, Tg)

    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    xg = _constrain(h.reshape(G, Tg, D), ("data", None, None))

    logits = (xg @ params["router"]).astype(jnp.float32)      # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # (G,Tg,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_e = expert_idx.reshape(G, Tg * k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), k)[None], (G, Tg * k))
    flat_g = gate_vals.reshape(G, Tg * k)
    order = jnp.argsort(flat_e, axis=1)
    se = jnp.take_along_axis(flat_e, order, axis=1)           # (G,Tk)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)

    # offsets[g, e] = first position of expert e in the sorted assignment
    # list (binary search — avoids materializing a (G, Tg*k, E) one-hot)
    offsets = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left")
    )(se).astype(jnp.int32)                                   # (G,E)
    counts = jnp.diff(jnp.concatenate(
        [offsets, jnp.full((G, 1), Tg * k, jnp.int32)], axis=1), axis=1)
    rank = jnp.arange(Tg * k, dtype=jnp.int32)[None] \
        - jnp.take_along_axis(offsets, se, axis=1)
    keep = rank < C
    rank_s = jnp.where(keep, rank, C)   # OOB => dropped by the scatter
    rank_c = jnp.where(keep, rank, 0)

    gidx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tg * k))
    gathered = jnp.take_along_axis(xg, st[..., None], axis=1)
    buf = jnp.zeros((G, E, C, D), xg.dtype).at[
        gidx, se, rank_s].set(gathered, mode="drop")
    buf = _constrain(buf, ("data", None, None, None))

    g_h = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    u_h = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    act = jax.nn.silu(g_h.astype(jnp.float32)).astype(u_h.dtype) * u_h
    y = jnp.einsum("gecf,efd->gecd", act, params["w_down"])   # (G,E,C,D)
    y = _constrain(y, ("data", None, None, None))

    per_assign = y[gidx, se, rank_c] \
        * (sg * keep).astype(y.dtype)[..., None]              # (G,Tk,D)
    out = jnp.zeros((G, Tg, D), y.dtype).at[
        gidx, st].add(per_assign)
    out = _constrain(out, ("data", None, None))

    frac_tokens = jnp.sum(counts, axis=0).astype(jnp.float32) / (T * k)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return x + out.reshape(B, S, D), aux


def moe_apply_ep(params, x, cfg: ModelConfig, mesh, *,
                 data_axes=("data",), model_axis: str = "model"):
    """Expert-parallel MoE via shard_map (§Perf iteration 3 — see
    EXPERIMENTS.md). GSPMD cannot partition the dispatch scatter/gather
    (it replicates the (G,E,C,D) buffer per device and reconciles with
    giant masked all-reduces), so we write the collective schedule by hand:

      * tokens sharded over the data axis, replicated over model;
      * each model shard scatters tokens into a buffer for ITS experts only
        (dispatch is entirely local — tokens are already resident);
      * local grouped einsum over E/model_size experts;
      * partial combine (scatter-add of this shard's expert outputs) and a
        single psum over the model axis.

    Requires num_experts % model_size == 0 (qwen3-moe; mixtral falls back
    to the grouped path). Under eq.-4-style normalized gates the psum is
    the exact combine."""
    from jax.sharding import PartitionSpec as P

    from repro.core.mesh import shard_map

    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    T = B * S
    n_model = mesh.shape[model_axis]
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    assert E % n_model == 0, (E, n_model)
    El = E // n_model
    Tg = T // n_data
    C = _capacity(cfg, Tg)

    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    xt = h.reshape(T, D)

    def body(xl, router, w_gate, w_up, w_down):
        # xl: (Tg, D); w_*: (El, D, F) local expert slice
        midx = jax.lax.axis_index(model_axis)
        logits = (xl @ router).astype(jnp.float32)            # (Tg, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
        flat_e = expert_idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tg), k)
        flat_g = gate_vals.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        offsets = jnp.searchsorted(se, jnp.arange(E)).astype(jnp.int32)
        rank = jnp.arange(Tg * k, dtype=jnp.int32) - offsets[se]
        keep = rank < C
        # local expert window [midx*El, (midx+1)*El); out-of-window or
        # over-capacity rows get OOB indices and are dropped by the scatter
        le = se - midx * El
        mine = (le >= 0) & (le < El) & keep
        le_c = jnp.clip(le, 0, El - 1)
        rank_s = jnp.where(keep, rank, C)
        rank_c = jnp.where(keep, rank, 0)
        buf = jnp.zeros((El, C, D), xl.dtype).at[le, rank_s].set(
            xl[st], mode="drop")
        g_h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        u_h = jnp.einsum("ecd,edf->ecf", buf, w_up)
        act = jax.nn.silu(g_h.astype(jnp.float32)).astype(u_h.dtype) * u_h
        y = jnp.einsum("ecf,efd->ecd", act, w_down)           # (El, C, D)
        per_assign = y[le_c, rank_c] \
            * (sg * mine).astype(y.dtype)[:, None]
        out = jnp.zeros((Tg, D), y.dtype).at[st].add(per_assign)
        out = jax.lax.psum(out, model_axis)                   # combine
        counts = jnp.diff(jnp.concatenate(
            [offsets, jnp.asarray([Tg * k], jnp.int32)]))
        frac_tokens = counts.astype(jnp.float32) / (Tg * k)
        frac_probs = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(frac_tokens * frac_probs)
        aux = jax.lax.pmean(aux, data_axes)
        return out, aux

    # expert-weight specs must match repro.sharding.param_spec
    wspec = P(model_axis, None, None)
    out, aux = shard_map(
        body, mesh,
        in_specs=(P(data_axes, None), P(None, None), wspec, wspec, wspec),
        out_specs=(P(data_axes, None), P()),
    )(xt, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return x + out.reshape(B, S, D), aux


def moe_apply_dense(params, x, cfg: ModelConfig):
    """Dense (all-experts) oracle for tests: computes every expert for every
    token and combines with the same top-k gates. O(T·E) FLOPs — tiny shapes
    only."""
    B, S, D = x.shape
    h = L.rmsnorm(params["norm"], x, cfg.norm_eps)
    xt = h.reshape(B * S, D)
    logits = (xt @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], expert_idx].set(gate_vals)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, params["w_up"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u
    y = jnp.einsum("tef,efd->ted", act, params["w_down"])
    out = jnp.einsum("ted,te->td", y, gates.astype(y.dtype))
    return x + out.reshape(B, S, D)
