"""Shared neural-net layers: RMSNorm, RoPE, MLPs, initializers.

All layers are pure functions over parameter pytrees (dicts of jnp arrays);
init functions return the pytree for one layer (callers stack them for
scanned stages).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = None):
    scale = scale if scale is not None else in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * params["scale"]


# ---------------------------------------------------------------------------
# RoPE


def rope_frequencies(head_dim: int, theta: float):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    return inv  # (head_dim//2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (...,S,1,hd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)


def mlp_init(key, cfg: ModelConfig, d_ff: int = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, f, dt),
            "w_up": dense_init(ks[1], d, f, dt),
            "w_down": dense_init(ks[2], f, d, dt),
        }
    return {
        "w_up": dense_init(ks[1], d, f, dt),
        "w_down": dense_init(ks[2], f, d, dt),
    }


def mlp_apply(params, x, act: str):
    if act == "swiglu":
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu((x @ params["w_up"]).astype(jnp.float32)).astype(x.dtype)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head


def embedding_init(key, cfg: ModelConfig):
    dt = dtype_of(cfg)
    p = {"embed": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                     jnp.float32) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(jax.random.fold_in(key, 1),
                                  cfg.d_model, cfg.vocab_size, dt)
    return p


def embed(params, tokens, cfg: ModelConfig):
    return jnp.take(params["embed"], tokens, axis=0)


def unembed(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["lm_head"]
