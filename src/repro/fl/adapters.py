"""Model adapters: bind a model family to the FL engine (init/loss/eval +
deterministic client batches). FedSpace schedules pytree updates, so any
adapter — MLP, the paper's DenseNet, or a zoo transformer — plugs in.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, StageSpec
from repro.data.fmow import NUM_CLASSES, SyntheticFmow
from repro.data.pipeline import ClientDataset
from repro.fl.registry import register_adapter
from repro.kernels.flash_attention.ops import flash_attention_bshd
from repro.kernels.rmsnorm.ops import rmsnorm as rmsnorm_op
from repro.models import attention as A
from repro.models import densenet as DN
from repro.models import layers as L
from repro.models import transformer as TF


def _xent(logits, labels):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


@register_adapter("mlp")
class MlpFmowAdapter:
    """Fast path: 62-class classification over feature vectors."""

    name = "mlp"

    def __init__(self, data: SyntheticFmow, clients: List[ClientDataset],
                 hidden: int = 64):
        self.data = data
        self.clients = clients
        self.hidden = hidden
        self._X_train = data.features(np.arange(data.spec.num_train),
                                      "train")
        self._y_train = data.train_labels
        self._X_val = data.features(np.arange(data.spec.num_val), "val")
        self._y_val = data.val_labels

    def init(self, key):
        ks = jax.random.split(key, 2)
        F, H = self._X_train.shape[1], self.hidden
        return {
            "w1": jax.random.normal(ks[0], (F, H)) * F ** -0.5,
            "b1": jnp.zeros(H),
            "w2": jax.random.normal(ks[1], (H, NUM_CLASSES)) * H ** -0.5,
            "b2": jnp.zeros(NUM_CLASSES),
        }

    def apply(self, params, X):
        h = jnp.tanh(X @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def loss(self, params, batch):
        X, y = batch
        return _xent(self.apply(params, X), y)

    def client_batch(self, client_idx: int, round_rng: int, batch_size: int,
                     num_batches: int):
        idx = self.clients[client_idx].batches(round_rng, batch_size,
                                               num_batches)
        if idx.shape[1] == 0:
            return None
        return (jnp.asarray(self._X_train[idx]),
                jnp.asarray(self._y_train[idx]))

    def _client_batch_indices(self, client_ids, round_rng: int,
                              batch_size: int, num_batches: int):
        """Index batches for a client set, restricted to the modal batch
        width so they stack. Returns (idx (M, num_batches, b), rows), rows
        being the positions of `client_ids` included; clients with empty
        shards or off-modal widths are left to the per-client fallback."""
        idxs = [self.clients[i].batches(round_rng, batch_size, num_batches)
                for i in client_ids]
        widths = [ix.shape[1] for ix in idxs]
        counts = {}
        for w in widths:
            if w > 0:
                counts[w] = counts.get(w, 0) + 1
        if not counts:
            return None, []
        modal = max(counts, key=lambda w: (counts[w], w))
        rows = [r for r, w in enumerate(widths) if w == modal]
        return np.stack([idxs[r] for r in rows]), rows

    def client_batch_many(self, client_ids, round_rng: int, batch_size: int,
                          num_batches: int):
        """Batched `client_batch`: one host gather + one device transfer
        for the whole client set (bit-identical batches to the per-client
        calls). Returns (stacked batch with leading dim M, rows)."""
        idx, rows = self._client_batch_indices(client_ids, round_rng,
                                               batch_size, num_batches)
        if not rows:
            return None, []
        return (jnp.asarray(self._X_train[idx]),
                jnp.asarray(self._y_train[idx])), rows

    def eval_batch(self, max_n: int = 2048):
        return jnp.asarray(self._X_val[:max_n]), \
            jnp.asarray(self._y_val[:max_n])

    def accuracy(self, params, max_n: int = 2048) -> float:
        X, y = self.eval_batch(max_n)
        pred = jnp.argmax(self.apply(params, X), axis=-1)
        return float(jnp.mean((pred == y).astype(jnp.float32)))

    def val_loss(self, params, max_n: int = 2048) -> float:
        X, y = self.eval_batch(max_n)
        return float(self.loss(params, (X, y)))


@register_adapter("densenet")
class DenseNetFmowAdapter(MlpFmowAdapter):
    """The paper's model family: DenseNet-style CNN over images, optional
    frozen prefix (transfer learning, §4.1)."""

    name = "densenet"

    def __init__(self, data: SyntheticFmow, clients: List[ClientDataset],
                 growth: int = 8, blocks=(2, 2, 2), stem: int = 16,
                 frozen_blocks: int = 0, val_n: int = 1024):
        self.data = data
        self.clients = clients
        self.growth, self.blocks, self.stem = growth, blocks, stem
        self.frozen_blocks = frozen_blocks
        self._y_train = data.train_labels
        self._val_X = jnp.asarray(
            data.images(np.arange(min(val_n, data.spec.num_val)), "val"))
        self._val_y = jnp.asarray(
            data.val_labels[:min(val_n, data.spec.num_val)])

    def init(self, key):
        return DN.densenet_init(key, num_classes=NUM_CLASSES,
                                growth=self.growth, blocks=self.blocks,
                                stem=self.stem)

    def trainable_mask(self, params):
        return DN.frozen_mask(params, self.frozen_blocks)

    def apply(self, params, X):
        return DN.densenet_apply(params, X)

    def loss(self, params, batch):
        X, y = batch
        return _xent(self.apply(params, X), y)

    def client_batch(self, client_idx, round_rng, batch_size, num_batches):
        idx = self.clients[client_idx].batches(round_rng, batch_size,
                                               num_batches)
        if idx.shape[1] == 0:
            return None
        imgs = np.stack([self.data.images(row, "train") for row in idx])
        return jnp.asarray(imgs), jnp.asarray(self._y_train[idx])

    def client_batch_many(self, client_ids, round_rng, batch_size,
                          num_batches):
        idx, rows = self._client_batch_indices(client_ids, round_rng,
                                               batch_size, num_batches)
        if not rows:
            return None, []
        s = self.data.spec.image_size
        imgs = self.data.images(idx.reshape(-1), "train").reshape(
            idx.shape + (s, s, 3))
        return (jnp.asarray(imgs), jnp.asarray(self._y_train[idx])), rows

    def eval_batch(self, max_n: int = 1024):
        # same slice as val_loss's default, so the utility sampler's
        # vmapped loss sees the exact batch the loop path evaluates
        return self._val_X[:max_n], self._val_y[:max_n]

    def accuracy(self, params, max_n: int = 1024) -> float:
        pred = jnp.argmax(self.apply(params, self._val_X[:max_n]), axis=-1)
        return float(jnp.mean((pred == self._val_y[:max_n]).astype(
            jnp.float32)))

    def val_loss(self, params, max_n: int = 1024) -> float:
        return float(self.loss(params,
                               (self._val_X[:max_n], self._val_y[:max_n])))


@register_adapter("transformer")
class TransformerFmowAdapter(MlpFmowAdapter):
    """Real payload on the wire: a small decoder stack
    (`repro.models.transformer` blocks — GQA attention with RoPE, swiglu
    FFN) classifying each fMoW feature vector as a token sequence, with
    the forward routed through the in-repo kernel dispatch
    (`kernels/flash_attention`, `kernels/rmsnorm`: compiled Pallas
    kernels on TPU, bit-identical jnp oracles everywhere else). Parameter
    pytrees are ~2 orders of magnitude heavier than the MLP's, so uplink
    compression and the link-budget byte accounting act on something
    real. Data plumbing (client batches, eval slices) is inherited from
    `MlpFmowAdapter` unchanged — the adapter contract is the same."""

    name = "transformer"

    def __init__(self, data: SyntheticFmow, clients: List[ClientDataset],
                 d_model: int = 32, num_layers: int = 2, num_heads: int = 4,
                 num_kv_heads: int = 2, d_ff: int = 64, seq_len: int = 8):
        super().__init__(data, clients)
        F = self._X_train.shape[1]
        # the feature vector is read as a sequence of S tokens of width
        # F/S; S is the largest value <= seq_len that divides F
        S = min(seq_len, F)
        while F % S:
            S -= 1
        self.seq_len = S
        self.cfg = ModelConfig(
            name="fl-transformer", arch_type="dense",
            num_layers=num_layers, d_model=d_model, num_heads=num_heads,
            num_kv_heads=num_kv_heads, d_ff=d_ff, vocab_size=NUM_CLASSES,
            stages=(StageSpec(("global",), num_layers),),
            param_dtype="float32")
        self.cfg.validate()

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        F, S = self._X_train.shape[1], self.seq_len
        return {
            "w_in": L.dense_init(ks[0], F // S, cfg.d_model, jnp.float32),
            "stage": TF.stage_init(ks[1], cfg, cfg.stages[0]),
            "final_norm": L.rmsnorm_init(cfg.d_model, jnp.float32),
            "head_w": L.dense_init(ks[2], cfg.d_model, NUM_CLASSES,
                                   jnp.float32),
            "head_b": jnp.zeros(NUM_CLASSES),
        }

    def apply(self, params, X):
        cfg = self.cfg
        B, S = X.shape[0], self.seq_len
        x = X.reshape(B, S, -1) @ params["w_in"]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def block(h, rep):
            # pre-norm attention + residual, with the normalization and
            # the attention itself on the kernel dispatch path
            a = rep["pos0"]["attn"]
            hn = rmsnorm_op(h, a["norm"]["scale"], cfg.norm_eps)
            q, k, v = A._project_qkv(a, hn, cfg, positions)
            o = flash_attention_bshd(q, k, v, causal=True, bq=S, bk=S)
            h = h + o.reshape(B, S, -1) @ a["wo"]
            f = rep["pos0"]["ffn"]
            hn = rmsnorm_op(h, f["norm"]["scale"], cfg.norm_eps)
            h = h + L.mlp_apply(f["mlp"], hn, cfg.mlp_act)
            return h, None

        x, _ = jax.lax.scan(block, x, params["stage"])
        x = rmsnorm_op(x, params["final_norm"]["scale"], cfg.norm_eps)
        return x[:, -1, :] @ params["head_w"] + params["head_b"]
