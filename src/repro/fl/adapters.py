"""Model adapters: bind a model family to the FL engine (init/loss/eval +
deterministic client batches). FedSpace schedules pytree updates, so any
adapter — MLP, the paper's DenseNet, or a zoo transformer — plugs in.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.fmow import NUM_CLASSES, SyntheticFmow
from repro.data.pipeline import ClientDataset
from repro.fl.registry import register_adapter
from repro.models import densenet as DN


def _xent(logits, labels):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


@register_adapter("mlp")
class MlpFmowAdapter:
    """Fast path: 62-class classification over feature vectors."""

    name = "mlp"

    def __init__(self, data: SyntheticFmow, clients: List[ClientDataset],
                 hidden: int = 64):
        self.data = data
        self.clients = clients
        self.hidden = hidden
        self._X_train = data.features(np.arange(data.spec.num_train),
                                      "train")
        self._y_train = data.train_labels
        self._X_val = data.features(np.arange(data.spec.num_val), "val")
        self._y_val = data.val_labels

    def init(self, key):
        ks = jax.random.split(key, 2)
        F, H = self._X_train.shape[1], self.hidden
        return {
            "w1": jax.random.normal(ks[0], (F, H)) * F ** -0.5,
            "b1": jnp.zeros(H),
            "w2": jax.random.normal(ks[1], (H, NUM_CLASSES)) * H ** -0.5,
            "b2": jnp.zeros(NUM_CLASSES),
        }

    def apply(self, params, X):
        h = jnp.tanh(X @ params["w1"] + params["b1"])
        return h @ params["w2"] + params["b2"]

    def loss(self, params, batch):
        X, y = batch
        return _xent(self.apply(params, X), y)

    def client_batch(self, client_idx: int, round_rng: int, batch_size: int,
                     num_batches: int):
        idx = self.clients[client_idx].batches(round_rng, batch_size,
                                               num_batches)
        if idx.shape[1] == 0:
            return None
        return (jnp.asarray(self._X_train[idx]),
                jnp.asarray(self._y_train[idx]))

    def _client_batch_indices(self, client_ids, round_rng: int,
                              batch_size: int, num_batches: int):
        """Index batches for a client set, restricted to the modal batch
        width so they stack. Returns (idx (M, num_batches, b), rows), rows
        being the positions of `client_ids` included; clients with empty
        shards or off-modal widths are left to the per-client fallback."""
        idxs = [self.clients[i].batches(round_rng, batch_size, num_batches)
                for i in client_ids]
        widths = [ix.shape[1] for ix in idxs]
        counts = {}
        for w in widths:
            if w > 0:
                counts[w] = counts.get(w, 0) + 1
        if not counts:
            return None, []
        modal = max(counts, key=lambda w: (counts[w], w))
        rows = [r for r, w in enumerate(widths) if w == modal]
        return np.stack([idxs[r] for r in rows]), rows

    def client_batch_many(self, client_ids, round_rng: int, batch_size: int,
                          num_batches: int):
        """Batched `client_batch`: one host gather + one device transfer
        for the whole client set (bit-identical batches to the per-client
        calls). Returns (stacked batch with leading dim M, rows)."""
        idx, rows = self._client_batch_indices(client_ids, round_rng,
                                               batch_size, num_batches)
        if not rows:
            return None, []
        return (jnp.asarray(self._X_train[idx]),
                jnp.asarray(self._y_train[idx])), rows

    def eval_batch(self, max_n: int = 2048):
        return jnp.asarray(self._X_val[:max_n]), \
            jnp.asarray(self._y_val[:max_n])

    def accuracy(self, params, max_n: int = 2048) -> float:
        X, y = self.eval_batch(max_n)
        pred = jnp.argmax(self.apply(params, X), axis=-1)
        return float(jnp.mean((pred == y).astype(jnp.float32)))

    def val_loss(self, params, max_n: int = 2048) -> float:
        X, y = self.eval_batch(max_n)
        return float(self.loss(params, (X, y)))


@register_adapter("densenet")
class DenseNetFmowAdapter(MlpFmowAdapter):
    """The paper's model family: DenseNet-style CNN over images, optional
    frozen prefix (transfer learning, §4.1)."""

    name = "densenet"

    def __init__(self, data: SyntheticFmow, clients: List[ClientDataset],
                 growth: int = 8, blocks=(2, 2, 2), stem: int = 16,
                 frozen_blocks: int = 0, val_n: int = 1024):
        self.data = data
        self.clients = clients
        self.growth, self.blocks, self.stem = growth, blocks, stem
        self.frozen_blocks = frozen_blocks
        self._y_train = data.train_labels
        self._val_X = jnp.asarray(
            data.images(np.arange(min(val_n, data.spec.num_val)), "val"))
        self._val_y = jnp.asarray(
            data.val_labels[:min(val_n, data.spec.num_val)])

    def init(self, key):
        return DN.densenet_init(key, num_classes=NUM_CLASSES,
                                growth=self.growth, blocks=self.blocks,
                                stem=self.stem)

    def trainable_mask(self, params):
        return DN.frozen_mask(params, self.frozen_blocks)

    def apply(self, params, X):
        return DN.densenet_apply(params, X)

    def loss(self, params, batch):
        X, y = batch
        return _xent(self.apply(params, X), y)

    def client_batch(self, client_idx, round_rng, batch_size, num_batches):
        idx = self.clients[client_idx].batches(round_rng, batch_size,
                                               num_batches)
        if idx.shape[1] == 0:
            return None
        imgs = np.stack([self.data.images(row, "train") for row in idx])
        return jnp.asarray(imgs), jnp.asarray(self._y_train[idx])

    def client_batch_many(self, client_ids, round_rng, batch_size,
                          num_batches):
        idx, rows = self._client_batch_indices(client_ids, round_rng,
                                               batch_size, num_batches)
        if not rows:
            return None, []
        s = self.data.spec.image_size
        imgs = self.data.images(idx.reshape(-1), "train").reshape(
            idx.shape + (s, s, 3))
        return (jnp.asarray(imgs), jnp.asarray(self._y_train[idx])), rows

    def eval_batch(self, max_n: int = 1024):
        # same slice as val_loss's default, so the utility sampler's
        # vmapped loss sees the exact batch the loop path evaluates
        return self._val_X[:max_n], self._val_y[:max_n]

    def accuracy(self, params, max_n: int = 1024) -> float:
        pred = jnp.argmax(self.apply(params, self._val_X[:max_n]), axis=-1)
        return float(jnp.mean((pred == self._val_y[:max_n]).astype(
            jnp.float32)))

    def val_loss(self, params, max_n: int = 1024) -> float:
        return float(self.loss(params,
                               (self._val_X[:max_n], self._val_y[:max_n])))
