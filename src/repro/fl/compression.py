"""Uplink update compression (beyond-paper extension).

The paper (§5, Related Works) notes communication-efficient FL — gradient
compression — is orthogonal to the scheduling contribution and "can be
combined together". This module provides the two standard primitives for
the satellite uplink (the scarce resource the whole paper is about) and a
simulation hook:

  * top-k sparsification (keep the k largest-magnitude entries per leaf);
  * symmetric int8 quantization with per-leaf scale.

Both are applied satellite-side to g_k before upload and inverted GS-side
before the eq.-4 aggregation; the compression ratio feeds the downlink
budget accounting.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class CompressedLeaf(NamedTuple):
    values: jnp.ndarray     # int8 quantized kept values
    indices: jnp.ndarray    # flat indices of kept entries (int32)
    scale: jnp.ndarray      # () f32 dequant scale
    shape: tuple


def compress_topk_int8(update, k_frac: float = 0.1):
    """Returns (compressed pytree, bytes_compressed, bytes_raw)."""
    total_raw = 0
    total_comp = 0

    def one(u):
        nonlocal total_raw, total_comp
        flat = u.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        k = max(1, int(n * k_frac))
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        kept = flat[idx]
        scale = jnp.maximum(jnp.max(jnp.abs(kept)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(kept / scale), -127, 127).astype(jnp.int8)
        total_raw += n * 4
        total_comp += k * (1 + 4)   # int8 value + int32 index
        return CompressedLeaf(values=q, indices=idx.astype(jnp.int32),
                              scale=scale, shape=tuple(u.shape))

    comp = jax.tree.map(one, update)
    return comp, total_comp, total_raw


def decompress(comp):
    def one(c):
        n = 1
        for d in c.shape:
            n *= d
        flat = jnp.zeros((n,), jnp.float32).at[c.indices].set(
            c.values.astype(jnp.float32) * c.scale)
        return flat.reshape(c.shape)

    return jax.tree.map(one, comp,
                        is_leaf=lambda x: isinstance(x, CompressedLeaf))


def roundtrip(update, k_frac: float = 0.1):
    """Compress + decompress — what the GS sees after an uplink with
    top-k/int8 compression. Returns (lossy update, compression ratio)."""
    comp, b_c, b_r = compress_topk_int8(update, k_frac)
    return decompress(comp), b_r / max(b_c, 1)


class QuantLeaf(NamedTuple):
    values: jnp.ndarray     # int8 quantized dense values (leaf shape)
    scale: jnp.ndarray      # () f32 dequant scale
    shape: tuple


def compress_int8(update):
    """Dense symmetric int8 quantization, per-leaf scale (no sparsity).

    Returns (compressed pytree, bytes_compressed, bytes_raw); the wire
    format is one int8 per entry plus one f32 scale per leaf.
    """
    total_raw = 0
    total_comp = 0

    def one(u):
        nonlocal total_raw, total_comp
        flat = u.reshape(-1).astype(jnp.float32)
        n = flat.shape[0]
        scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        total_raw += n * 4
        total_comp += n * 1 + 4     # int8 values + f32 scale
        return QuantLeaf(values=q, scale=scale, shape=tuple(u.shape))

    comp = jax.tree.map(one, update)
    return comp, total_comp, total_raw


def decompress_int8(comp):
    def one(c):
        return (c.values.astype(jnp.float32) * c.scale).reshape(c.shape)

    return jax.tree.map(one, comp,
                        is_leaf=lambda x: isinstance(x, QuantLeaf))


def roundtrip_int8(update):
    """Dense-int8 analogue of `roundtrip`: (lossy update, ratio)."""
    comp, b_c, b_r = compress_int8(update)
    return decompress_int8(comp), b_r / max(b_c, 1)


def uplink_bytes_ratio(k_frac: float = 0.0, *, int8: bool = False) -> float:
    """Analytic compressed/raw bytes ratio of one uplinked update.

    Mirrors the per-leaf accounting of `compress_topk_int8` /
    `compress_int8` in the large-leaf limit, where the per-leaf scale is
    amortized away: raw entries cost 4 bytes (f32); a kept top-k entry
    costs 5 (int8 value + int32 index), so top-k lands at
    ``k_frac * 5 / 4``; dense int8 keeps every entry at 1 byte, so 1/4.
    ``k_frac`` in {0, None} with ``int8=False`` is the uncompressed wire
    (ratio 1.0). Top-k takes precedence over ``int8`` — its kept values
    are already int8-quantized. The link-budget layer multiplies
    `LinkConfig.model_mb` by this ratio to get the effective upload size
    feeding `transfer_windows`/`LinkGate.need_up`.
    """
    if k_frac:
        return float(k_frac) * 5.0 / 4.0
    if int8:
        return 1.0 / 4.0
    return 1.0
