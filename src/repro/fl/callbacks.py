"""Hooks for `repro.fl.engine.SimulationEngine`.

A callback observes the run at well-defined events and may request an
early stop; it never mutates protocol state. Events (all optional):

    on_run_begin(engine)
    on_window_end(engine, window)
    on_aggregate_end(engine, window, info)     # info: ig, n_aggregated, ...
    on_eval(engine, window, metrics)           # metrics: accuracy, ...
    on_run_end(engine, result)
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

from repro.ckpt.checkpoint import save_pytree


class Callback:
    """No-op base; subclass and override the events you care about."""

    def on_run_begin(self, engine):
        pass

    def on_window_end(self, engine, window: int):
        pass

    def on_aggregate_end(self, engine, window: int, info: dict):
        pass

    def on_eval(self, engine, window: int, metrics: dict):
        pass

    def on_run_end(self, engine, result):
        pass


class JsonlMetricsCallback(Callback):
    """Stream eval metrics (and the final summary) to a JSONL file — one
    JSON object per line, flushed as it happens, so a long simulation can
    be tailed/plotted live."""

    def __init__(self, path: str):
        self.path = path
        self._f = None

    def on_run_begin(self, engine):
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # one file = one run: truncate so a re-run with the same path
        # doesn't interleave events from a previous (possibly crashed) run
        self._f = open(self.path, "w")
        self._write({"event": "run_begin", "scheme": engine.scheduler.name,
                     "num_windows": engine.num_windows, "K": engine.K})

    def on_eval(self, engine, window, metrics):
        self._write({"event": "eval", **metrics})

    def on_run_end(self, engine, result):
        if self._f is None:
            return
        self._write({"event": "run_end", **result.summary()})
        self._f.close()
        self._f = None

    def _write(self, obj: dict):
        self._f.write(json.dumps(obj) + "\n")
        self._f.flush()


class CheckpointCallback(Callback):
    """Persist the global model every `every` global updates (and at run
    end) as npz pytrees under `directory`."""

    def __init__(self, directory: str, every: int = 10):
        self.directory = directory
        self.every = max(1, every)

    def on_aggregate_end(self, engine, window, info):
        if info["ig"] % self.every == 0:
            self._save(engine, info["ig"])

    def on_run_end(self, engine, result):
        self._save(engine, engine.ig)

    def _save(self, engine, ig: int):
        save_pytree(os.path.join(self.directory, f"model_v{ig:06d}.npz"),
                    engine.params)


class EarlyStopCallback(Callback):
    """Stop when validation accuracy has not improved by `min_delta` for
    `patience` consecutive evals."""

    def __init__(self, patience: int = 5, min_delta: float = 0.0):
        self.patience = patience
        self.min_delta = min_delta
        self.best: Optional[float] = None
        self.stale_evals = 0

    def on_run_begin(self, engine):
        self.best, self.stale_evals = None, 0

    def on_eval(self, engine, window, metrics):
        acc = metrics["accuracy"]
        if self.best is None or acc > self.best + self.min_delta:
            self.best, self.stale_evals = acc, 0
        else:
            self.stale_evals += 1
            if self.stale_evals >= self.patience:
                engine.request_stop()


class ProgressCallback(Callback):
    """Human-readable one-liners per eval (quickstart/launcher UX)."""

    def __init__(self, prefix: str = ""):
        self.prefix = prefix
        self._t0 = None

    def on_run_begin(self, engine):
        self._t0 = time.time()

    def on_eval(self, engine, window, metrics):
        print(f"{self.prefix}[{engine.scheduler.name}] day "
              f"{metrics['day']:5.2f}  acc={metrics['accuracy']:.3f}  "
              f"val_loss={metrics['val_loss']:.3f}  "
              f"updates={metrics['global_updates']}  "
              f"({time.time() - self._t0:.0f}s)", flush=True)
