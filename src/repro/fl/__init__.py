from repro.fl.adapters import DenseNetFmowAdapter, MlpFmowAdapter
from repro.fl.client import make_client_update
from repro.fl.simulation import SimResult, run_simulation
