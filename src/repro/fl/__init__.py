"""Public surface of `repro.fl`.

Attribute access is lazy (PEP 562): importing `repro.fl.registry` from the
lower `repro.core` layer must not drag in the jax-heavy adapter/engine
modules (which themselves import `repro.core`) — the registries are the
one piece both layers share.
"""
from __future__ import annotations

import importlib

_LAZY = {
    # adapters / client
    "DenseNetFmowAdapter": "repro.fl.adapters",
    "MlpFmowAdapter": "repro.fl.adapters",
    "make_client_update": "repro.fl.client",
    # engine + shim
    "EngineConfig": "repro.fl.engine",
    "SimResult": "repro.fl.engine",
    "SimulationEngine": "repro.fl.engine",
    "T0_MINUTES": "repro.fl.engine",
    "run_simulation": "repro.fl.simulation",
    # declarative experiment layer
    "AdapterConfig": "repro.fl.api",
    "ConstellationConfig": "repro.fl.api",
    "DatasetConfig": "repro.fl.api",
    "FLExperiment": "repro.fl.api",
    "Federation": "repro.fl.api",
    "LinkConfig": "repro.fl.api",
    "PartitionConfig": "repro.fl.api",
    "SchedulerConfig": "repro.fl.api",
    # callbacks
    "Callback": "repro.fl.callbacks",
    "CheckpointCallback": "repro.fl.callbacks",
    "EarlyStopCallback": "repro.fl.callbacks",
    "JsonlMetricsCallback": "repro.fl.callbacks",
    "ProgressCallback": "repro.fl.callbacks",
    # registries
    "ADAPTERS": "repro.fl.registry",
    "PARTITIONS": "repro.fl.registry",
    "SCHEDULERS": "repro.fl.registry",
    "register_adapter": "repro.fl.registry",
    "register_partition": "repro.fl.registry",
    "register_scheduler": "repro.fl.registry",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    try:
        module = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.fl' has no attribute {name!r}") from None
    return getattr(importlib.import_module(module), name)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
