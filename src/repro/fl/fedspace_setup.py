"""Wiring for FedSpace's first phase (paper §3.2, Fig. 5): pretrain a source
trajectory, generate (staleness-vector, status) -> Δf samples against it
(eq. 12), and fit the utility regressor û used by the schedule search.

The paper uses the same task's dataset as the source D^s (its §4.3
simplification); we do the same — the adapter provides both the source
trajectory training and the client updates.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler import make_scheduler
from repro.core.utility import (MLPRegressor, RandomForestRegressor,
                                generate_utility_samples)
from repro.fl.client import make_batched_client_update, make_client_update


def pretrain_trajectory(adapter, *, rounds: int = 40, clients_per_round: int
                        = 16, local_steps: int = 4, client_lr: float = 0.05,
                        seed: int = 0) -> List:
    """Simulated ideal-FL trajectory {w^0..w^Imax} on the source dataset:
    each round aggregates fresh updates from a random client subset (no
    connectivity constraints — this runs entirely at the GS)."""
    rng = np.random.default_rng(seed)
    params = adapter.init(jax.random.PRNGKey(seed))
    client_update = make_client_update(adapter, local_steps=local_steps,
                                       lr=client_lr)
    K = len(adapter.clients)
    traj = [params]
    for r in range(rounds):
        picks = rng.choice(K, min(clients_per_round, K), replace=False)
        updates = [client_update(params, int(k), round_rng=10_000 + r)
                   for k in picks]
        stack = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
        delta = jax.tree.map(lambda u: jnp.mean(u, axis=0), stack)
        params = jax.tree.map(lambda p, d: p + d, params, delta)
        traj.append(params)
    return traj


def fit_utility_regressor(adapter, trajectory, *, kind: str = "rf",
                          n_samples: int = 300, s_max: int = 8,
                          clients_per_sample: int = 48,
                          local_steps: int = 4, client_lr: float = 0.05,
                          batch_size: int = 32, seed: int = 0):
    client_update = make_client_update(adapter, local_steps=local_steps,
                                       lr=client_lr)

    def upd_fn(base, ci, rng_int):
        # eq. 4 normalization by participating count happens inside
        # generate_utility_samples
        return client_update(base, ci, round_rng=int(rng_int),
                             batch_size=batch_size)

    # the engine's batched machinery vectorizes sample generation: vmapped
    # client training grouped by base checkpoint + vmapped loss over the
    # perturbed checkpoints. Adapters without `eval_batch` fall back to
    # the per-sample loop (upd_fn / val_loss) automatically.
    batched_loss = None
    if hasattr(adapter, "eval_batch"):
        val_batch = adapter.eval_batch()
        batched_loss = jax.jit(jax.vmap(
            lambda p: adapter.loss(p, val_batch)))

    X, y = generate_utility_samples(
        jax.random.PRNGKey(seed), trajectory, upd_fn,
        lambda p: adapter.val_loss(p),
        num_clients=len(adapter.clients), n_samples=n_samples, s_max=s_max,
        clients_per_sample=clients_per_sample, seed=seed,
        batch_fn=lambda ci, rng_int: adapter.client_batch(
            ci, int(rng_int), batch_size, local_steps),
        batched_update_fn=make_batched_client_update(
            adapter, local_steps=local_steps, lr=client_lr),
        batched_loss_fn=batched_loss)
    reg = (RandomForestRegressor(seed=seed) if kind == "rf"
           else MLPRegressor(seed=seed))
    reg.fit(X, y)
    # in-sample fit quality (diagnostic)
    pred = reg.predict(X)
    ss = 1.0 - np.sum((pred - y) ** 2) / max(np.sum((y - y.mean()) ** 2),
                                             1e-12)
    return reg, {"r2_in_sample": float(ss), "n": len(y),
                 "y_mean": float(y.mean()), "y_std": float(y.std())}


def build_utility_regressor(adapter, *, regressor_kind="rf",
                            pretrain_rounds=40, utility_samples=250,
                            local_steps=16, client_lr=1.0,
                            clients_per_round=24, clients_per_sample=48,
                            s_max=8, seed=0):
    """Phase 1 alone (the expensive part): pretrain the source trajectory
    and fit û. Returns (regressor, diagnostics) so callers comparing
    several FedSpace schedule configurations can reuse one regressor."""
    traj = pretrain_trajectory(adapter, rounds=pretrain_rounds,
                               clients_per_round=clients_per_round,
                               local_steps=local_steps,
                               client_lr=client_lr, seed=seed)
    return fit_utility_regressor(adapter, traj, kind=regressor_kind,
                                 n_samples=utility_samples, s_max=s_max,
                                 clients_per_sample=clients_per_sample,
                                 local_steps=local_steps,
                                 client_lr=client_lr, seed=seed)


def build_fedspace_scheduler(adapter, *, I0=24, n_min=None, n_max=None,
                             num_candidates=5000, s_max=8, seed=0,
                             **setup_kw):
    """Full phase-1 wiring: pretrain the source trajectory, fit û, and
    return the configured FedSpace scheduler plus the regressor diagnostics.
    This is THE calibrated setup shared by examples/benchmarks/launchers;
    extra keywords go to `build_utility_regressor`."""
    reg, diag = build_utility_regressor(adapter, s_max=s_max, seed=seed,
                                        **setup_kw)
    sched = make_scheduler("fedspace", regressor=reg, I0=I0, n_min=n_min,
                           n_max=n_max, num_candidates=num_candidates,
                           s_max=s_max, seed=seed)
    return sched, diag
