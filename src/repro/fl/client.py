"""Satellite-side local training (paper eq. 3): E SGD steps from the last
received global model; the update g_k = w_k^E - w_k^0 is held until the next
ground-station contact."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def make_client_update(adapter, *, local_steps: int, lr: float,
                       trainable_mask=None):
    """Returns update_fn(base_params, batches) -> g_k (pytree delta)."""

    @jax.jit
    def update_fn(params, batches):
        def body(p, batch):
            g = jax.grad(adapter.loss)(p, batch)
            if trainable_mask is not None:
                g = jax.tree.map(lambda g_, m: g_ * m, g, trainable_mask)
            p = jax.tree.map(lambda w, g_: w - lr * g_, p, g)
            return p, None

        final, _ = jax.lax.scan(body, params, batches)
        return jax.tree.map(lambda a, b: a - b, final, params)

    def client_update(base_params, client_idx: int, round_rng: int,
                      batch_size: int = 32):
        batch = adapter.client_batch(client_idx, round_rng, batch_size,
                                     local_steps)
        if batch is None:      # satellite with an empty shard
            return jax.tree.map(jnp.zeros_like, base_params)
        return update_fn(base_params, batch)

    return client_update
