"""Satellite-side local training (paper eq. 3): E SGD steps from the last
received global model; the update g_k = w_k^E - w_k^0 is held until the next
ground-station contact.

Two entry points share one update body: `make_client_update` (one satellite
per call — utility-sample generation, pretraining) and
`make_batched_client_update` (a vmapped stack of satellites per call — the
engine's aggregation hot path, with the optional top-k compression
roundtrip fused into the same jitted program). vmap keeps per-satellite
results bit-identical to the sequential calls, so the batched engine
reproduces the seed trajectory exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fl.compression import roundtrip, roundtrip_int8


def _make_update_fn(adapter, *, lr: float, trainable_mask=None):
    def update_fn(params, batches):
        def body(p, batch):
            g = jax.grad(adapter.loss)(p, batch)
            if trainable_mask is not None:
                g = jax.tree.map(lambda g_, m: g_ * m, g, trainable_mask)
            p = jax.tree.map(lambda w, g_: w - lr * g_, p, g)
            return p, None

        final, _ = jax.lax.scan(body, params, batches)
        return jax.tree.map(lambda a, b: a - b, final, params)

    return update_fn


def make_client_update(adapter, *, local_steps: int, lr: float,
                       trainable_mask=None):
    """Returns update_fn(base_params, client_idx, round_rng) -> g_k
    (pytree delta)."""
    update_fn = jax.jit(_make_update_fn(adapter, lr=lr,
                                        trainable_mask=trainable_mask))

    def client_update(base_params, client_idx: int, round_rng: int,
                      batch_size: int = 32):
        batch = adapter.client_batch(client_idx, round_rng, batch_size,
                                     local_steps)
        if batch is None:      # satellite with an empty shard
            return jax.tree.map(jnp.zeros_like, base_params)
        return update_fn(base_params, batch)

    return client_update


def make_batched_client_update(adapter, *, local_steps: int, lr: float,
                               trainable_mask=None, uplink_topk: float = 0.0,
                               uplink_int8: bool = False):
    """Returns update_many(base_params, batches) -> stacked g_k.

    `batches` is the per-satellite batch pytree stacked on a leading axis M;
    the base model is shared (broadcast). One jitted program trains all M
    satellites and, when `uplink_topk > 0` (or `uplink_int8`), applies the
    top-k/int8 (or dense-int8) uplink roundtrip to each update before
    returning — no per-satellite dispatch, no host round-trip between
    training and compression. Top-k takes precedence over dense int8.
    """
    update_fn = _make_update_fn(adapter, lr=lr,
                                trainable_mask=trainable_mask)

    @jax.jit
    def update_many(base_params, batches):
        u = jax.vmap(update_fn, in_axes=(None, 0))(base_params, batches)
        if uplink_topk > 0.0:
            u = jax.vmap(lambda t: roundtrip(t, uplink_topk)[0])(u)
        elif uplink_int8:
            u = jax.vmap(lambda t: roundtrip_int8(t)[0])(u)
        return u

    return update_many
