"""Back-compat entry point for the FL simulation.

The protocol loop now lives in `repro.fl.engine.SimulationEngine`
(overridable steps + callback hooks); `run_simulation` is a thin wrapper
kept so pre-engine call sites and tests continue to work unchanged.
Prefer the declarative layer for new code:

    from repro.fl.api import FLExperiment, Federation
    result = Federation.from_experiment(FLExperiment(...)).run()
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.scheduler import Scheduler
from repro.fl.engine import (EngineConfig, SimResult, SimulationEngine,
                             T0_MINUTES)

__all__ = ["run_simulation", "SimResult", "SimulationEngine",
           "EngineConfig", "T0_MINUTES"]


def run_simulation(C: np.ndarray, adapter, scheduler: Scheduler, *,
                   local_steps: int = 4, batch_size: int = 32,
                   client_lr: float = 0.05, server_lr: float = 1.0,
                   alpha: float = 0.5, eval_every: int = 8,
                   target_acc: Optional[float] = None,
                   max_windows: Optional[int] = None,
                   repeat_connectivity: int = 1,
                   s_max: int = 8, seed: int = 0,
                   init_params=None, stop_at_target: bool = True,
                   uplink_topk: float = 0.0,
                   ) -> SimResult:
    """Run one scheme over the connectivity sequence C (I, K)."""
    config = EngineConfig(
        local_steps=local_steps, batch_size=batch_size,
        client_lr=client_lr, server_lr=server_lr, alpha=alpha,
        eval_every=eval_every, target_acc=target_acc,
        max_windows=max_windows,
        # legacy semantics: values <= 1 never tiled (0 is NOT the engine's
        # auto-tile sentinel here)
        repeat_connectivity=max(1, repeat_connectivity),
        s_max=s_max, seed=seed, stop_at_target=stop_at_target,
        uplink_topk=uplink_topk)
    return SimulationEngine(C, adapter, scheduler, config,
                            init_params=init_params).run()
