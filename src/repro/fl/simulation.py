"""Event-driven FL simulation over the connectivity sequence (Algorithm 1).

Time advances in T0 windows (15 min each). At window i the GS:
  receives pending updates from connected satellites, asks the scheduler
  whether to aggregate (a^i), applies the staleness-compensated update
  (eq. 4) when a^i = 1, and broadcasts the current model.

The engine mirrors exactly the protocol the schedule-search simulator
(repro.core.staleness) assumes, with real gradients; the per-satellite
integer state is the same SatState, so FedSpaceScheduler reads it directly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointStore
from repro.core import staleness as SS
from repro.core.aggregation import apply_aggregation
from repro.core.scheduler import Scheduler
from repro.fl.client import make_client_update

T0_MINUTES = 15.0


@dataclass
class SimResult:
    scheme: str
    accuracy: List[float] = field(default_factory=list)
    val_loss: List[float] = field(default_factory=list)
    eval_windows: List[int] = field(default_factory=list)
    staleness_hist: np.ndarray = None
    idle_connections: int = 0
    total_connections: int = 0
    num_global_updates: int = 0
    num_aggregated_gradients: int = 0
    windows_run: int = 0
    time_to_target_days: Optional[float] = None
    target_acc: Optional[float] = None

    def days(self, window: int) -> float:
        return window * T0_MINUTES / 60.0 / 24.0

    def summary(self) -> dict:
        return {
            "scheme": self.scheme,
            "final_acc": self.accuracy[-1] if self.accuracy else None,
            "best_acc": max(self.accuracy) if self.accuracy else None,
            "time_to_target_days": self.time_to_target_days,
            "global_updates": self.num_global_updates,
            "aggregated_gradients": self.num_aggregated_gradients,
            "idle_connections": self.idle_connections,
            "total_connections": self.total_connections,
            "staleness_hist": (self.staleness_hist.tolist()
                               if self.staleness_hist is not None else None),
        }


def run_simulation(C: np.ndarray, adapter, scheduler: Scheduler, *,
                   local_steps: int = 4, batch_size: int = 32,
                   client_lr: float = 0.05, server_lr: float = 1.0,
                   alpha: float = 0.5, eval_every: int = 8,
                   target_acc: Optional[float] = None,
                   max_windows: Optional[int] = None,
                   repeat_connectivity: int = 1,
                   s_max: int = 8, seed: int = 0,
                   init_params=None, stop_at_target: bool = True,
                   uplink_topk: float = 0.0,
                   ) -> SimResult:
    """Run one scheme over the connectivity sequence C (I, K)."""
    if repeat_connectivity > 1:
        C = np.concatenate([C] * repeat_connectivity, axis=0)
    I, K = C.shape
    if max_windows:
        I = min(I, max_windows)
    scheduler.reset()

    key = jax.random.PRNGKey(seed)
    params = adapter.init(key) if init_params is None else init_params
    mask = adapter.trainable_mask(params) \
        if hasattr(adapter, "trainable_mask") else None
    client_update = make_client_update(adapter, local_steps=local_steps,
                                       lr=client_lr, trainable_mask=mask)

    store = CheckpointStore(keep_in_memory=s_max + 26)
    store.put(0, params)
    ig = 0
    state = SS.bootstrap_state(K)
    version = np.zeros(K, np.int64)       # mirrors state.version
    pending = np.zeros(K, np.int64)       # base version of pending update
    buffered_base = np.full(K, -1, np.int64)

    res = SimResult(scheme=scheduler.name, target_acc=target_acc)
    res.staleness_hist = np.zeros(s_max + 1, np.int64)
    status = float(adapter.val_loss(params))

    for i in range(I):
        conn = np.flatnonzero(C[i])
        # 1. uploads
        for k in conn:
            res.total_connections += 1
            if pending[k] >= 0:
                buffered_base[k] = pending[k]
                pending[k] = -1
            elif version[k] == ig:
                res.idle_connections += 1
        n_buf = int((buffered_base >= 0).sum())

        # 2. scheduler decision
        state = SS.SatState(jnp.asarray(version, jnp.int32),
                            jnp.asarray(pending, jnp.int32),
                            jnp.asarray(buffered_base, jnp.int32))
        a = scheduler.decide(i, n_in_buffer=n_buf, K=K, state=state, ig=ig,
                             connectivity=C, status=status)

        # 3. aggregate (eq. 4)
        if a and n_buf > 0:
            ks = np.flatnonzero(buffered_base >= 0)
            stal = ig - buffered_base[ks]
            updates = []
            for k in ks:
                base = store.get(int(buffered_base[k]))
                u = client_update(base, int(k), round_rng=i)
                if uplink_topk > 0.0:   # beyond-paper: compressed uplink
                    from repro.fl.compression import roundtrip
                    u, _ = roundtrip(u, uplink_topk)
                updates.append(u)
            stack = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
            params = apply_aggregation(params, stack,
                                       jnp.asarray(stal), alpha=alpha,
                                       server_lr=server_lr)
            ig += 1
            store.put(ig, params)
            refs = [v for v in np.concatenate([pending, buffered_base])
                    if v >= 0]
            store.prune(min(refs) if refs else ig)
            res.num_global_updates += 1
            res.num_aggregated_gradients += len(ks)
            cl = np.clip(stal, 0, s_max)
            np.add.at(res.staleness_hist, cl, 1)
            buffered_base[:] = -1

        # 4. downloads
        for k in conn:
            if version[k] < ig:
                version[k] = ig
                pending[k] = ig

        res.windows_run = i + 1
        if (i + 1) % eval_every == 0 or i == I - 1:
            acc = adapter.accuracy(params)
            status = float(adapter.val_loss(params))
            res.accuracy.append(acc)
            res.val_loss.append(status)
            res.eval_windows.append(i)
            if (target_acc is not None and acc >= target_acc
                    and res.time_to_target_days is None):
                res.time_to_target_days = res.days(i)
                if stop_at_target:
                    break
    return res
