"""String-keyed component registries for the FL experiment layer.

Every pluggable piece of the federation — aggregation schedulers, model
adapters, dataset partitioners — is registered by name so experiments can
be declared as data (`FLExperiment`) instead of hand-wired Python. New
components plug in from anywhere without touching engine or registry code:

    from repro.fl.registry import register_scheduler

    @register_scheduler("my-policy")
    class MyScheduler(Scheduler):
        ...

    make_scheduler("my-policy")          # or FLExperiment(scheduler=
                                         #   SchedulerConfig("my-policy"))

This module is intentionally dependency-free (no jax / numpy / repro
imports) so the lowest layers can register into it without import cycles.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Optional


class Registry:
    """A named mapping from string keys to factories (classes or callables).

    Lookups raise a `KeyError` that lists what IS registered — a typo in a
    config should cost seconds, not a stack-trace safari.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, Callable[..., Any]] = {}

    # -- registration -------------------------------------------------------

    def register(self, name: str,
                 obj: Optional[Callable[..., Any]] = None):
        """Register `obj` under `name`; usable as a decorator.

        Re-registering an existing name overwrites it (last wins) so tests
        and notebooks can iterate on a component without restarting.
        """
        def _do(target: Callable[..., Any]) -> Callable[..., Any]:
            self._entries[name] = target
            return target

        return _do if obj is None else _do(obj)

    # -- lookup -------------------------------------------------------------

    def get(self, name: str) -> Callable[..., Any]:
        try:
            return self._entries[name]
        except KeyError:
            known = ", ".join(repr(n) for n in self.names()) or "<none>"
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered {self.kind}s: "
                f"{known}") from None

    def build(self, name: str, *args, **kwargs) -> Any:
        """Instantiate the registered factory: `registry.build(name, ...)`"""
        return self.get(name)(*args, **kwargs)

    def names(self) -> List[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {self.names()})"


SCHEDULERS = Registry("scheduler")
ADAPTERS = Registry("adapter")
PARTITIONS = Registry("partition")
CONSTELLATIONS = Registry("constellation")


def register_scheduler(name: str, obj=None):
    """Class/function decorator: register an aggregation-policy factory."""
    return SCHEDULERS.register(name, obj)


def register_constellation(name: str, obj=None):
    """Function decorator: register a constellation-preset factory
    `f(*, ground=None, **overrides) -> ConstellationSpec` (see
    `repro.core.connectivity` for the built-in scenario suite)."""
    return CONSTELLATIONS.register(name, obj)


def register_adapter(name: str, obj=None):
    """Class/function decorator: register a model-adapter factory
    `f(data, clients, **params) -> adapter`."""
    return ADAPTERS.register(name, obj)


def register_partition(name: str, obj=None):
    """Function decorator: register a partitioner
    `f(data, K, spec, *, days, seed, **params) -> List[np.ndarray]`."""
    return PARTITIONS.register(name, obj)
