"""Declarative experiment layer: `FLExperiment` (what to run, as data) and
`Federation` (the wired-up world that runs it).

One experiment = constellation x dataset x partition x adapter x scheduler
x training/link options. Every component is referenced by registry name
(`repro.fl.registry`), so a new scheduler/adapter/partitioner registered
via decorator is immediately selectable here — no engine edits, no new
kwargs on a god-function:

    exp = FLExperiment(
        constellation=ConstellationConfig(num_satellites=40, days=3.0),
        dataset=DatasetConfig(num_train=4000, num_val=1000, noise=2.2),
        partition=PartitionConfig(kind="noniid"),
        adapter=AdapterConfig(kind="mlp", params={"hidden": 48}),
        scheduler=SchedulerConfig(kind="fedbuff", params={"M": 20}),
        train=EngineConfig(local_steps=16, client_lr=1.0, target_acc=0.35),
    )
    result = Federation.from_experiment(exp).run()

`Federation` owns all the wiring that used to be copy-pasted across
examples/, benchmarks/, and launch/: spec -> connectivity -> data ->
partition -> clients -> adapter -> scheduler (including FedSpace's
phase-1 trajectory/regressor when the scheduler needs it).

Engines built here run device-resident by default: every registered
scheduler exposes `device_plan`, so the window loop executes as chunked
jitted scans over the shared Algorithm-1 transitions (see
`repro.fl.engine`). Set `EngineConfig(fast_loop=False)` to force the
per-window host loop — e.g. for callbacks that must observe protocol
state at every window.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.core import connectivity as CN
from repro.core.faults import FaultConfig, fault_trace
from repro.core.isl import ISLConfig, build_isl
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition, noniid_partition
from repro.data.pipeline import make_clients
import repro.fl.adapters  # noqa: F401 — registers the built-in adapters
from repro.fl.compression import uplink_bytes_ratio
from repro.fl.engine import EngineConfig, SimResult, SimulationEngine
from repro.fl.registry import (ADAPTERS, PARTITIONS, SCHEDULERS,
                               register_partition)

__all__ = ["ConstellationConfig", "DatasetConfig", "PartitionConfig",
           "AdapterConfig", "SchedulerConfig", "LinkConfig", "ISLConfig",
           "FaultConfig", "FLExperiment", "Federation"]


# --------------------------------------------------------------------------
# sub-configs


@dataclass
class ConstellationConfig:
    """Constellation + simulated horizon for the connectivity sequence.

    Two ways to pick the constellation:
      * ad hoc: `num_satellites` (+ `spec_overrides`) builds a single-shell
        Planet-Flock-like spec, as before;
      * by preset: `preset` names a registered scenario
        (`repro.fl.registry.CONSTELLATIONS` — "flock191",
        "starlink40/120/400/1000", ...) whose satellite count and shell
        layout come from the registry; `num_satellites` is then ignored.

    `ground` selects a named ground-station network
    (`repro.core.connectivity.GROUND_NETWORKS`: "dense12", "mid4",
    "sparse1") for either mode; "" keeps the spec's default. `days` sets
    the propagated horizon (96 15-minute windows per day).
    """
    num_satellites: int = 40
    days: float = 3.0
    spec_overrides: Dict = field(default_factory=dict)  # ConstellationSpec
    preset: str = ""                   # CONSTELLATIONS registry key
    ground: str = ""                   # GROUND_NETWORKS key ("" = default)

    def build_spec(self):
        """Resolve to the `ConstellationSpec` alone (no propagation).
        Both modes share `repro.core.connectivity.resolve_spec`, so
        `ground` and `spec_overrides` have identical semantics (and error
        messages) with and without a preset."""
        ground = self.ground or None
        if self.preset:
            return CN.constellation_preset(self.preset, ground=ground,
                                           **self.spec_overrides)
        return CN.resolve_spec(
            CN.ConstellationSpec(num_satellites=self.num_satellites),
            ground, self.spec_overrides)

    def build(self):
        """Resolve to (ConstellationSpec, connectivity matrix C)."""
        spec = self.build_spec()
        return spec, CN.connectivity_sets(spec, days=self.days)


@dataclass
class DatasetConfig:
    """Synthetic-fMoW knobs (see repro.data.fmow.FmowSpec)."""
    num_train: int = 4000
    num_val: int = 1000
    noise: float = 0.9
    image_size: int = 16
    feature_dim: int = 32
    seed: int = 1234

    def to_spec(self) -> FmowSpec:
        return FmowSpec(num_train=self.num_train, num_val=self.num_val,
                        noise=self.noise, image_size=self.image_size,
                        feature_dim=self.feature_dim, seed=self.seed)


@dataclass
class PartitionConfig:
    kind: str = "iid"                      # registry key
    params: Dict = field(default_factory=dict)
    seed: Optional[int] = None             # None -> experiment seed


@dataclass
class AdapterConfig:
    kind: str = "mlp"                      # registry key
    params: Dict = field(default_factory=dict)


@dataclass
class SchedulerConfig:
    kind: str = "fedbuff"                  # registry key
    params: Dict = field(default_factory=dict)
    # FedSpace phase-1 knobs (pretrain_rounds, utility_samples,
    # local_steps, client_lr, ...) consumed by build_fedspace_scheduler
    # when kind == "fedspace" and no regressor is supplied in params.
    setup: Dict = field(default_factory=dict)


@dataclass
class LinkConfig:
    """Satellite-to-GS link model options: uplink compression plus the
    capacity-constrained link budget (rates, model size, per-station
    concurrent-contact capacity).

    Every budget field uses 0 as its "unconstrained" sentinel, so the
    default LinkConfig is the geometry-only model of previous releases —
    a contact window is a free, instantaneous transfer — bit-for-bit.
    Setting `model_mb` together with a rate makes transfers span
    ``ceil(model_mb * 8 / rate_mbps / substep)`` contact substeps
    (`repro.core.connectivity.transfer_windows`), and `gs_capacity`
    bounds how many satellites one ground station serves concurrently
    (surplus contacts are deterministically turned away —
    `repro.core.connectivity.resolve_contention`). The `Federation`
    builder resolves non-trivial configs into a
    `repro.core.connectivity.LinkBudget` consumed by the engine, the
    schedulers, and the eq.-13 schedule search."""
    uplink_topk: float = 0.0      # >0: top-k+int8 compressed uplink
    uplink_int8: bool = False     # dense int8 uplink (when no top-k)
    uplink_mbps: float = 0.0      # sat->GS rate; 0 = unconstrained
    downlink_mbps: float = 0.0    # GS->sat rate; 0 = unconstrained
    model_mb: float = 0.0         # model transfer size; 0 = instantaneous
    gs_capacity: int = 0          # concurrent contacts/station; 0 = no cap

    def __post_init__(self):
        for name in ("uplink_topk", "uplink_mbps", "downlink_mbps",
                     "model_mb", "gs_capacity"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(f"LinkConfig.{name} must be >= 0, got {v}")
        if self.uplink_topk > 1:
            raise ValueError(f"LinkConfig.uplink_topk must be in [0, 1], "
                             f"got {self.uplink_topk}")

    @property
    def constrained(self) -> bool:
        """True when any field makes links non-instantaneous or contended
        — i.e. the experiment needs a resolved `LinkBudget`."""
        return (self.gs_capacity > 0
                or (self.model_mb > 0
                    and (self.uplink_mbps > 0 or self.downlink_mbps > 0)))


# --------------------------------------------------------------------------
# the experiment spec


@dataclass
class FLExperiment:
    """One experiment, as data: constellation x dataset x partition x
    adapter x scheduler x training/link options, every component selected
    by registry name. Build and run it with
    `Federation.from_experiment(exp).run()`. `seed` is the experiment-wide
    default that unset partition/train seeds fall back to."""
    name: str = ""
    constellation: ConstellationConfig = field(
        default_factory=ConstellationConfig)
    dataset: DatasetConfig = field(default_factory=DatasetConfig)
    partition: PartitionConfig = field(default_factory=PartitionConfig)
    adapter: AdapterConfig = field(default_factory=AdapterConfig)
    scheduler: SchedulerConfig = field(default_factory=SchedulerConfig)
    train: EngineConfig = field(default_factory=EngineConfig)
    link: LinkConfig = field(default_factory=LinkConfig)
    # optional inter-satellite-link layer (repro.core.isl.ISLConfig):
    # resolved against the constellation's plane geometry by
    # `Federation.from_experiment`; None (default) = no ISLs, bit-identical
    # to previous releases. It only changes runs whose scheduler declares
    # an `isl_mode` (the "intra_plane" / "isl_async" schedulers), so one
    # ISL-configured world serves with/without-ISL scheduler comparisons.
    isl: Optional[ISLConfig] = None
    # optional fault-injection layer (repro.core.faults.FaultConfig):
    # satellite churn, station outages, weather-degraded links. Resolved
    # to a deterministic per-window FaultTrace against this constellation
    # and horizon by `Federation.from_experiment` (shared by
    # `with_scheduler` clones, so scheduler comparisons degrade under one
    # identical fault world); None — or a trivial config — keeps every
    # run bit-identical to previous releases.
    faults: Optional[FaultConfig] = None
    seed: int = 0

    def describe(self) -> dict:
        """The full experiment as a nested dict (for logs/manifests)."""
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# built-in partitioners (registry signature: f(data, K, spec, *, days,
# seed, **params))


@register_partition("iid")
def _iid_partition(data, K, spec, *, days, seed, **params):
    return iid_partition(data.spec.num_train, K, seed)


@register_partition("noniid")
def _noniid_partition(data, K, spec, *, days, seed, **params):
    return noniid_partition(data.train_zones, K, spec, days=days,
                            seed=seed, **params)


# --------------------------------------------------------------------------
# the builder


class Federation:
    """A fully wired world: constellation, connectivity, data, adapter,
    scheduler — ready to produce `SimulationEngine`s."""

    def __init__(self, *, experiment: FLExperiment, spec, C: np.ndarray,
                 data, adapter, scheduler=None,
                 scheduler_diag: Optional[dict] = None,
                 link_budget=None, isl=None, faults=None,
                 _regressor_cache: Optional[Dict] = None,
                 _counts_cache: Optional[Dict] = None):
        self.experiment = experiment
        self.spec = spec
        self.C = C
        self.data = data
        self.adapter = adapter
        self.scheduler = scheduler
        self.scheduler_diag = scheduler_diag or {}
        # resolved LinkBudget when the experiment's LinkConfig is
        # capacity/rate-constrained (None = geometry-only links)
        self.link_budget = link_budget
        # resolved repro.core.isl.ISL runtime when the experiment declares
        # an ISLConfig (None = satellites only talk to ground stations)
        self.isl = isl
        # resolved repro.core.faults.FaultTrace when the experiment
        # declares a non-trivial FaultConfig (None = fault-free world)
        self.faults = faults
        # FedSpace phase-1 (regressor, diag) keyed by setup knobs, shared
        # across with_scheduler clones of this world
        self._regressor_cache: Dict = ({} if _regressor_cache is None
                                       else _regressor_cache)
        # per-station contact counts (CN.station_windows), resolved at
        # most once per world and shared by with_faults clones — fault
        # traces with station outages need them, and the propagation
        # sweep behind them is the expensive part of a fault re-resolve
        self._counts_cache: Dict = ({} if _counts_cache is None
                                    else _counts_cache)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_experiment(cls, exp: FLExperiment) -> "Federation":
        """Wire a world from an `FLExperiment`: resolve the constellation
        (preset or ad hoc) to connectivity, build dataset/partition/
        clients/adapter from their registries, then the scheduler —
        including FedSpace's phase-1 regressor when required. A
        rate/capacity-constrained `LinkConfig` is instead resolved to the
        `LinkBudget` transfer layer over the same spec and horizon, and C
        comes from its `visible` matrix — bit-identical to
        `connectivity_sets` (tests/test_link_budget.py), so the orbital
        propagation sweep runs once, not twice."""
        budget = counts = None
        fcfg = exp.faults
        if fcfg is not None and fcfg.trivial:
            fcfg = None           # trivial config == no faults at all
        days = exp.constellation.days
        if exp.link.constrained:
            spec = exp.constellation.build_spec()
            lk = exp.link
            if fcfg is not None:
                # the fault trace needs the per-station contact counts
                # (station-up reach); share one propagation sweep with
                # the budget instead of running it twice
                counts = CN.station_windows(spec, days=days)
            # uploads carry compressed updates: the effective uplink
            # payload shrinks by the analytic bytes ratio of the resolved
            # compression settings (train fields win over LinkConfig, the
            # same precedence `engine()` applies), so fewer contact units
            # complete an upload. Ratio 1.0 leaves need_up bit-identical.
            tk = exp.train.uplink_topk
            topk = tk if tk is not None else lk.uplink_topk
            i8 = exp.train.uplink_int8
            int8 = bool(i8 if i8 is not None else lk.uplink_int8)
            budget = CN.link_budget(
                spec, days=days,
                uplink_mbps=lk.uplink_mbps, downlink_mbps=lk.downlink_mbps,
                model_mb=lk.model_mb, gs_capacity=lk.gs_capacity,
                counts=counts,
                uplink_mb=lk.model_mb * uplink_bytes_ratio(topk, int8=int8))
            C = budget.visible
        else:
            spec, C = exp.constellation.build()
            if fcfg is not None and fcfg.outages:
                # station outages on the station-collapsed geometry path
                # need per-station counts to know which contacts die
                counts = CN.station_windows(spec, days=days)
        faults = None if fcfg is None else fault_trace(
            fcfg, C.shape[0], K=spec.num_satellites,
            num_stations=len(spec.ground_stations), counts=counts)
        data = SyntheticFmow(exp.dataset.to_spec())
        pseed = exp.partition.seed if exp.partition.seed is not None \
            else exp.seed
        parts = PARTITIONS.build(exp.partition.kind, data,
                                 spec.num_satellites, spec,
                                 days=exp.constellation.days, seed=pseed,
                                 **exp.partition.params)
        adapter = ADAPTERS.build(exp.adapter.kind, data,
                                 make_clients(parts), **exp.adapter.params)
        isl = build_isl(spec, exp.isl) if exp.isl is not None else None
        fed = cls(experiment=exp, spec=spec, C=C, data=data,
                  adapter=adapter, link_budget=budget, isl=isl,
                  faults=faults)
        if counts is not None:
            fed._counts_cache["station_windows"] = counts
        fed.scheduler, diag = fed._build_scheduler(exp)
        fed.scheduler_diag = diag
        return fed

    def _build_scheduler(self, exp: FLExperiment):
        cfg = exp.scheduler
        if cfg.kind == "fedspace" and "regressor" not in cfg.params:
            # phase 1 (paper §3.2) needs the adapter: pretrain the source
            # trajectory and fit û before the scheduler exists. Cached per
            # setup so comparing schedule configs reuses one regressor.
            from repro.fl.fedspace_setup import build_utility_regressor
            # s_max must agree between regressor training and schedule
            # search — resolve once, apply to both phases
            s_max = cfg.params.get("s_max", cfg.setup.get("s_max", 8))
            setup = {"seed": exp.seed, **cfg.setup, "s_max": s_max}
            key = repr(sorted(setup.items()))
            if key not in self._regressor_cache:
                self._regressor_cache[key] = build_utility_regressor(
                    self.adapter, **setup)
            reg, diag = self._regressor_cache[key]
            params = {"seed": exp.seed, **cfg.params, "s_max": s_max,
                      "regressor": reg}
            return SCHEDULERS.build("fedspace", **params), diag
        return SCHEDULERS.build(cfg.kind, **cfg.params), {}

    def connectivity_summary(self, *, windows_per_day: int = 96) -> dict:
        """Scalar Fig.-2 connectivity statistics for this world's C
        (per-window set sizes and per-satellite contacts/day; see
        `repro.core.connectivity.connectivity_stats`). The underlying
        per-window/per-satellite arrays are dropped so the result is
        JSON-serializable for experiment logs."""
        stats = CN.connectivity_stats(self.C, windows_per_day)
        return {k: v for k, v in stats.items()
                if k not in ("sizes", "contacts_per_day")}

    def with_scheduler(self, scheduler: Union[str, SchedulerConfig],
                       **params) -> "Federation":
        """Same world, different aggregation policy — for scheduler
        comparisons without rebuilding constellation/data (or, for
        FedSpace variants with identical `setup`, the utility regressor)."""
        cfg = (SchedulerConfig(kind=scheduler, params=params)
               if isinstance(scheduler, str) else scheduler)
        exp = dataclasses.replace(self.experiment, scheduler=cfg)
        fed = Federation(experiment=exp, spec=self.spec, C=self.C,
                         data=self.data, adapter=self.adapter,
                         link_budget=self.link_budget, isl=self.isl,
                         faults=self.faults,
                         _regressor_cache=self._regressor_cache,
                         _counts_cache=self._counts_cache)
        fed.scheduler, fed.scheduler_diag = fed._build_scheduler(exp)
        return fed

    def with_faults(self, faults: Optional[FaultConfig]) -> "Federation":
        """Same world — constellation, links, data, adapter, scheduler
        config — under a different fault scenario: only the deterministic
        per-window `FaultTrace` is re-resolved (None or a trivial config
        clears faults). `from_experiment` with a changed `faults` field
        would rebuild — and re-propagate — everything; this reuses the
        orbital sweep, the dataset, and the scheduler setup (including a
        FedSpace regressor), which is what makes fault-grid sweeps
        (`repro.fl.sweep.run_sweep`) cheap to assemble."""
        fcfg = faults
        if fcfg is not None and fcfg.trivial:
            fcfg = None
        exp = dataclasses.replace(self.experiment, faults=faults)
        counts = None
        if fcfg is not None and (self.link_budget is not None
                                 or fcfg.outages):
            counts = self._counts_cache.get("station_windows")
            if counts is None:
                counts = CN.station_windows(
                    self.spec, days=exp.constellation.days)
                self._counts_cache["station_windows"] = counts
        trace = None if fcfg is None else fault_trace(
            fcfg, self.C.shape[0], K=self.spec.num_satellites,
            num_stations=len(self.spec.ground_stations), counts=counts)
        fed = Federation(experiment=exp, spec=self.spec, C=self.C,
                         data=self.data, adapter=self.adapter,
                         link_budget=self.link_budget, isl=self.isl,
                         faults=trace,
                         _regressor_cache=self._regressor_cache,
                         _counts_cache=self._counts_cache)
        fed.scheduler, fed.scheduler_diag = fed._build_scheduler(exp)
        return fed

    # -- running ------------------------------------------------------------

    def engine(self, *, callbacks: Sequence = (), init_params=None,
               mesh=None) -> SimulationEngine:
        """Build a ready-to-run `SimulationEngine` for this world
        (optionally with callbacks / a custom initial model). `mesh`
        shards the satellite axis of the run across a device mesh
        (`repro.core.mesh.sim_mesh()`) — trajectory-bit-identical to the
        default single-device run."""
        # explicitly-set train fields win; unset (None) ones fall back to
        # the experiment-wide seed / LinkConfig compression settings
        exp = self.experiment
        cfg = exp.train
        seed = cfg.seed if cfg.seed is not None else exp.seed
        topk = cfg.uplink_topk if cfg.uplink_topk is not None \
            else exp.link.uplink_topk
        int8 = cfg.uplink_int8 if cfg.uplink_int8 is not None \
            else exp.link.uplink_int8
        cfg = dataclasses.replace(cfg, seed=seed, uplink_topk=topk,
                                  uplink_int8=int8)
        return SimulationEngine(self.C, self.adapter, self.scheduler, cfg,
                                callbacks=callbacks,
                                init_params=init_params,
                                link_budget=self.link_budget,
                                isl=self.isl, faults=self.faults,
                                mesh=mesh)

    def run(self, *, callbacks: Sequence = (),
            init_params=None) -> SimResult:
        """Build the engine and execute the run; returns its SimResult."""
        return self.engine(callbacks=callbacks,
                           init_params=init_params).run()
