"""Incremental eq.-13 replanning as a service (ROADMAP north-star serving
story: ground-assisted scheduling, arXiv 2109.01348).

Every FedSpace aggregation event used to recompute the full candidate scan
over the I0 horizon, yet consecutive horizons overlap in all but one
window. `ReplanService` holds the marks/scan state of the previous replan
and scores only the delta:

* A **full plan** at window j draws a candidate pool, runs
  `repro.core.search.scan_candidates` (the cache-collecting twin of
  `score_candidates`) and keeps, per candidate, the predicted per-event
  utilities (`win_util`) and the final scan state/version (the frontier).
* A **delta replan** at window j+1 filters the pool to candidates whose
  window-j bit equals the realized action (their simulated trajectories
  coincide with reality on the overlap, so every cached per-event utility
  over [j+1, j+I0) is *bit-identical* to what a fresh rescan would
  compute), extends each survivor with a drawn bit for the newly revealed
  window j+I0, and simulates **only that window** — one vmapped
  `repro.core.search.step_candidates` step over the candidates that
  scheduled it — before re-reducing scores at the same (R, n_cap) shape a
  full rescan would use. Selection is therefore bit-identical to
  `score_candidates` + `select_candidate` on the same pool and state
  (gated by the `replan` section of `benchmarks/hotpaths.py`).

The cache is invalidated — the service falls back to a full rescan — on:
  * **drift**: the caller's state is not the one the cached rollouts
    predicted (e.g. fault masking, an out-of-band aggregation, or a
    caller that executed a different action than the returned schedule);
  * **narrowing**: the global version grew past the int16 narrowing guard
    the cached frontier states were scanned under;
  * **horizon / window**: I0 or K changed, or the request is not the
    next consecutive window;
  * **link / connectivity view**: the overlapping connectivity or grant
    rows differ from the cached view (weather, outages, a new budget);
  * **status**: the training-status feature T changed (every cached
    utility was predicted at the old T);
  * **pool**: survivor filtering would drop the pool below `min_pool`;
  * **mesh**: the service runs sharded full rescans but never caches
    under a satellite-axis mesh.
Fallbacks are counted per reason in `ReplanService.stats`.

Forest transfer: the regressor is handed in once (`regressor=`) and the
serving path never refits — the histogram featurization is K-agnostic
(`repro.core.utility.transfer_ready`), so a forest fitted on flock191
serves starlink40/120/400/1000 unchanged. `examples/serve_replan.py`
wraps the service in a persistent-jit server loop (the
`examples/serve_decode.py` pattern): connectivity columns stream in,
replan requests are answered without recompilation.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS
from repro.core.search import (event_positions, infer_n_range,
                               random_candidates, scan_candidates,
                               score_candidates, select_candidate,
                               step_candidates)
from repro.core.utility import featurize_jnp, transfer_ready

__all__ = ["ReplanService"]


class _Cache:
    """The previous replan's scan artifacts (see module docstring)."""

    def __init__(self, *, window, cands, Cw, grant, need_up, need_dn,
                 win_util, end_state, end_ig, state_dtype, pre_state,
                 pre_ig, winner_bit, status, density, n_max):
        self.window = window          # absolute window the plan answered
        self.cands = cands            # (R, I0) int32 pool
        self.Cw = Cw                  # (I0, K) bool horizon view
        self.grant = grant            # (I0, K) int grants or None
        self.need_up = need_up
        self.need_dn = need_dn
        self.win_util = win_util      # (R, I0) f32 per-event utilities
        self.end_state = end_state    # stacked SatState, frontier (host)
        self.end_ig = end_ig          # (R,) frontier versions
        self.state_dtype = state_dtype
        self.pre_state = pre_state    # int32 (K,) search state of the plan
        self.pre_ig = pre_ig
        self.winner_bit = winner_bit  # realized action the cache assumes
        self.status = status
        self.density = density        # pool aggregation density at draw
        self.n_max = n_max            # cap for extension bits
        self.pending = None           # (conn, gate) of an unadvanced window


def _np_state(state: SS.SatState) -> SS.SatState:
    """Host int32 copy of a (K,) SatState (progress/relay pass through)."""
    return SS.SatState(*(np.asarray(x, np.int32) for x in state[:3]),
                       None if state.progress is None
                       else np.asarray(state.progress, np.int32),
                       None if state.relay is None
                       else np.asarray(state.relay, np.int32))


def _rows(state: SS.SatState, sel) -> SS.SatState:
    """Index the leading (candidate) axis of a stacked SatState."""
    return jax.tree.map(lambda x: x[sel], state)


def _bucket(n: int) -> int:
    """Next power-of-two batch bucket. The one-window `step_candidates`
    kernel is jitted per batch shape, and the survivor pool decays across
    delta steps — bucketing keeps the serving loop at a handful of
    compiled shapes instead of one compile per request (which would dwarf
    the <100 ms answer budget). Padded rows duplicate a real row and are
    sliced off before use."""
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _state_equal(a: SS.SatState, b: SS.SatState) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


class ReplanService:
    """Persistent eq.-13 replanner with delta-window scoring.

    One service holds one regressor (the forest-transfer handoff: fit
    once, serve any constellation) and the scan cache of its latest plan.
    `replan` answers a request; `maintain` runs the deferred frontier
    advance between requests so answer latency stays at delta cost.

    Args:
      regressor: fitted utility model û; must pass
        `repro.core.utility.transfer_ready` for this `s_max` (the
        serving path never refits).
      I0: planning-horizon length (windows).
      num_candidates: pool size R of a full plan.
      n_min / n_max: aggregation-count range for candidate draws; None
        infers both from û per plan (paper §3.2, `infer_n_range`).
      s_max: staleness clip — must match the regressor's feature width.
      seed: service rng (extension bits + full-plan draws when the caller
        does not pass its own rng).
      min_pool: survivor floor below which a delta request full-rescans.
      mesh: optional satellite-axis device mesh for full rescans
        (`repro.core.mesh`); delta caching is disabled under a mesh.
    """

    def __init__(self, regressor, *, I0: int = 24,
                 num_candidates: int = 5000, n_min: Optional[int] = None,
                 n_max: Optional[int] = None, s_max: int = 8, seed: int = 0,
                 min_pool: int = 256, mesh=None):
        if not transfer_ready(regressor, s_max=s_max):
            raise ValueError(
                "regressor is not transfer-ready for s_max="
                f"{s_max}: it must expose predict_device and (if fitted "
                "through .fit) a matching feature width — see "
                "repro.core.utility.transfer_ready")
        self.regressor = regressor
        self.I0 = I0
        self.num_candidates = num_candidates
        self.n_min = n_min
        self.n_max = n_max
        self.s_max = s_max
        self.seed = seed
        self.min_pool = min_pool
        self.mesh = mesh
        self._rng = np.random.default_rng(seed)
        self._cache: Optional[_Cache] = None
        self.stats = {"full": 0, "delta": 0, "invalidated": {}}
        self.last_mode: Optional[str] = None
        self.last_reason: Optional[str] = None

    # -- cache management ---------------------------------------------------

    def invalidate(self, reason: str = "external"):
        """Drop the scan cache (the next request full-rescans). Engine
        `reset()` cascades here so re-runs never reuse a stale plan."""
        if self._cache is not None:
            self.stats["invalidated"][reason] = \
                self.stats["invalidated"].get(reason, 0) + 1
        self._cache = None

    @property
    def pool(self) -> Optional[np.ndarray]:
        """The current candidate pool (read-only copy), for parity gates."""
        return None if self._cache is None else self._cache.cands.copy()

    def _gate(self, grant_row, need_up, need_dn):
        if grant_row is None:
            return None
        return SS.LinkGate(jnp.asarray(np.asarray(grant_row), jnp.int32),
                           jnp.int32(need_up), jnp.int32(need_dn))

    def maintain(self):
        """Deferred delta-step bookkeeping: advance every cached frontier
        state through the window revealed by the last delta replan. The
        answer path only simulates the revealed window for candidates that
        *scheduled* it; this advances the full pool so the next request is
        again one-window work. Call it between requests (the server loop
        does); a replan arriving first runs it inline, trading latency for
        correctness."""
        c = self._cache
        if c is None or c.pending is None:
            return
        conn, gate = c.pending
        S = int(c.end_ig.shape[0])
        sel = np.concatenate([np.arange(S),
                              np.zeros(_bucket(S) - S, np.int64)])
        _, st, g = step_candidates(
            jax.tree.map(jnp.asarray, _rows(c.end_state, sel)),
            jnp.asarray(c.end_ig[sel]), jnp.asarray(conn),
            jnp.asarray(c.cands[sel, -1]), gate, s_max=self.s_max)
        c.end_state = jax.tree.map(lambda x: np.asarray(x)[:S], st)
        c.end_ig = np.asarray(g)[:S]
        c.pending = None

    # -- request path -------------------------------------------------------

    def replan(self, window: int, C_window: np.ndarray, state: SS.SatState,
               ig: int, status: float, *, link: Optional[SS.LinkGate] = None,
               rng: Optional[np.random.Generator] = None,
               n_min: Optional[int] = None,
               n_max: Optional[int] = None) -> np.ndarray:
        """Answer one replan request: the winning (I0,) schedule for the
        horizon [window, window + I0).

        Arguments mirror `repro.core.search.fedspace_search`: `C_window`
        is the (I0, K) future connectivity (effective, capacity-resolved
        when budgets are modeled), `state`/`ig` the search-ready protocol
        state (post-upload at `window`, grant-inverted under a link budget
        — `FedSpaceScheduler._search_state`), `status` the training
        status T, `link` the horizon's `LinkGate` slice. `rng` drives the
        candidate draw of a full plan (the FedSpace scheduler passes its
        own so routed plans are bit-identical to unrouted ones);
        extension bits of delta steps always come from the service rng.

        Consecutive-window requests with an intact cache are answered by
        the delta path; anything else falls back to a full rescan (see
        the module docstring for the invalidation table).
        """
        C_window = np.asarray(C_window, bool)
        self.maintain()
        reason = self._delta_blocker(window, C_window, state, ig, status,
                                     link)
        if reason is None:
            self.last_mode, self.last_reason = "delta", None
            self.stats["delta"] += 1
            return self._delta(window, C_window, state, ig, status, link)
        if self._cache is not None and reason != "cold":
            self.invalidate(reason)
        self.last_mode, self.last_reason = "full", reason
        self.stats["full"] += 1
        return self._full(window, C_window, state, ig, status, link, rng,
                          n_min, n_max)

    # -- full plan ----------------------------------------------------------

    def _full(self, window, Cw, state, ig, status, link, rng, n_min,
              n_max):
        I0, K = Cw.shape
        rng = rng if rng is not None else self._rng
        n_min = n_min if n_min is not None else self.n_min
        n_max = n_max if n_max is not None else self.n_max
        if n_min is None or n_max is None:
            inf_min, inf_max = infer_n_range(
                self.regressor, float(Cw.mean(axis=1).sum()) / I0 * K,
                I0, status, s_max=self.s_max, K=K)
            n_min = n_min if n_min is not None else inf_min
            n_max = n_max if n_max is not None else inf_max
        cands = random_candidates(rng, I0, n_min, n_max,
                                  self.num_candidates)
        if self.mesh is not None:
            scores = score_candidates(cands, Cw, state, ig, self.regressor,
                                      status, s_max=self.s_max, link=link,
                                      mesh=self.mesh)
            art = None
        else:
            scores, art = scan_candidates(cands, Cw, state, ig,
                                          self.regressor, status,
                                          s_max=self.s_max, link=link)
        w = select_candidate(cands, scores)
        if art is not None:
            self._cache = _Cache(
                window=window, cands=cands, Cw=Cw.copy(),
                grant=None if link is None
                else np.asarray(link.grant, np.int32).copy(),
                need_up=0 if link is None else int(link.need_up),
                need_dn=0 if link is None else int(link.need_dn),
                win_util=art["win_util"], end_state=art["end_state"],
                end_ig=art["end_ig"], state_dtype=art["state_dtype"],
                pre_state=_np_state(state), pre_ig=int(ig),
                winner_bit=int(cands[w, 0]), status=float(status),
                density=float(cands.mean()), n_max=n_max)
        return cands[w].copy()

    # -- delta path ---------------------------------------------------------

    def _delta_blocker(self, window, Cw, state, ig, status, link):
        """None when the cached scan can answer this request, else the
        invalidation reason (module docstring)."""
        c = self._cache
        if c is None:
            return "cold"
        if self.mesh is not None:
            return "mesh"
        if window != c.window + 1:
            return "window"
        if Cw.shape != c.Cw.shape:
            return "horizon"
        if float(status) != c.status:
            return "status"
        if not np.array_equal(Cw[:-1], c.Cw[1:]):
            return "connectivity"
        if (link is None) != (c.grant is None):
            return "link"
        if link is not None:
            if (int(link.need_up) != c.need_up
                    or int(link.need_dn) != c.need_dn
                    or not np.array_equal(
                        np.asarray(link.grant, np.int32)[:-1],
                        c.grant[1:])):
                return "link"
        if (c.state_dtype == np.int16
                and not (int(ig) + self.I0 + 1
                         < np.iinfo(np.int16).max - 1)):
            return "narrowing"
        if np.count_nonzero(c.cands[:, 0] == c.winner_bit) < self.min_pool:
            return "pool"
        if self._drifted(window, Cw, state, ig, link):
            return "drift"
        return None

    def _drifted(self, window, Cw, state, ig, link) -> bool:
        """True when the caller's state is not the one the cached rollouts
        predicted. The cached scan entered window `window` with the state
        produced by realizing the winner's bit at window-1; a fresh rescan
        would enter it by (idempotently) re-uploading the caller's
        search-ready state. The two coincide — and every cached mark stays
        valid — iff both post-upload states are equal, so that is the
        check (one (K,)-sized transition each, exact integer compare)."""
        c = self._cache
        prev_gate = self._gate(None if c.grant is None else c.grant[0],
                               c.need_up, c.need_dn)
        pre = jax.tree.map(jnp.asarray, c.pre_state)
        after, g_after, _ = SS.step(
            pre, jnp.int32(c.pre_ig), jnp.asarray(c.Cw[0]),
            jnp.asarray(bool(c.winner_bit)), s_max=self.s_max,
            collect="none", link=prev_gate)
        if int(g_after) != int(ig):
            return True
        gate0 = self._gate(None if link is None
                           else np.asarray(link.grant, np.int32)[0],
                           c.need_up, c.need_dn)
        conn0 = jnp.asarray(Cw[0])
        predicted, _ = SS.upload_step(after, g_after, conn0, gate0)
        given = jax.tree.map(lambda x: jnp.asarray(np.asarray(x),
                                                   jnp.int32), state)
        rescanned, _ = SS.upload_step(given, jnp.int32(int(ig)), conn0,
                                      gate0)
        return not _state_equal(predicted, rescanned)

    def _delta(self, window, Cw, state, ig, status, link):
        c = self._cache
        keep = c.cands[:, 0] == c.winner_bit
        base = c.cands[keep]
        S = base.shape[0]
        # extend every survivor with a drawn bit for the revealed window
        # (service rng; capped so no candidate exceeds the draw-time n_max)
        n_now = base[:, 1:].sum(axis=1)
        draw = (self._rng.random(S) < c.density).astype(np.int32)
        new_bits = np.where(n_now < c.n_max, draw, 0).astype(np.int32)
        cands = np.concatenate([base[:, 1:], new_bits[:, None]], axis=1)
        win_util = np.concatenate(
            [c.win_util[keep, 1:], np.zeros((S, 1), np.float32)], axis=1)
        end_state = _rows(c.end_state, keep)
        end_ig = c.end_ig[keep]
        # simulate ONLY the newly revealed window, only for candidates
        # that scheduled it — same marks→hist→featurize→predict pipeline
        # as the full scan, from the cached per-candidate frontier
        conn_new = Cw[-1]
        gate_new = self._gate(None if link is None
                              else np.asarray(link.grant, np.int32)[-1],
                              c.need_up, c.need_dn)
        rows1 = np.flatnonzero(new_bits == 1)
        if rows1.size:
            m = rows1.size
            sel = np.concatenate(
                [rows1, np.full(_bucket(m) - m, rows1[0], np.int64)])
            marks, _, _ = step_candidates(
                jax.tree.map(jnp.asarray, _rows(end_state, sel)),
                jnp.asarray(end_ig[sel]), jnp.asarray(conn_new),
                jnp.asarray(new_bits[sel]), gate_new, s_max=self.s_max)
            hists = SS.hist_from_marks(marks, s_max=self.s_max,
                                       dtype=jnp.int16)
            util = self.regressor.predict_device(
                featurize_jnp(hists, jnp.float32(status)))
            win_util[rows1, -1] = np.asarray(util)[:m]
        # re-reduce at the same per-row (n_cap,) shape a full rescan would
        # use, so the masked sum is bit-identical to score_candidates.
        # Rows are bucket-padded with zeros (per-row sums unaffected) so
        # the eager device reduction reuses a handful of compiled shapes
        # instead of recompiling for every survivor count.
        idx, mask = event_positions(cands)
        util_ev = np.take_along_axis(win_util, idx, axis=1)
        pad = _bucket(S) - S
        if pad:
            util_ev = np.concatenate(
                [util_ev, np.zeros((pad, util_ev.shape[1]), np.float32)])
            mask = np.concatenate(
                [mask, np.zeros((pad, mask.shape[1]), mask.dtype)])
        scores = np.asarray((jnp.asarray(util_ev)
                             * jnp.asarray(mask, jnp.float32))
                            .sum(axis=1))[:S]
        w = select_candidate(cands, scores)
        # roll the cache forward; the frontier advance is deferred to
        # maintain() so it stays off the answer path
        c.window = window
        c.cands = cands
        c.Cw = Cw.copy()
        if link is not None:
            c.grant = np.asarray(link.grant, np.int32).copy()
        c.win_util = win_util
        c.end_state = end_state
        c.end_ig = end_ig
        c.pre_state = _np_state(state)
        c.pre_ig = int(ig)
        c.winner_bit = int(cands[w, 0])
        c.pending = (conn_new.copy(), gate_new)
        return cands[w].copy()
