"""Batched experiment sweeps: many protocol runs as ONE device dispatch.

FedSpace's evaluation — and every study in `examples/` — is a grid of
variants over a shared world: scheduler hyperparameters, fault scenarios,
link knobs, seeds. Sequentially that is hundreds of engine runs, each
paying per-chunk dispatch and host transfers for a protocol whose windows
are already fully vectorized. This module runs the *entire* fast-loop
trajectory of every variant in a single `jit(vmap(...))` over a leading
variant axis: one compile per variant *shape* (same scheduler indicator,
same horizon/K, same optional columns), one dispatch per group.

The sweep body (`_sweep_run`) mirrors `repro.fl.engine._scan_impl`'s
window body exactly — same fault re-entry, ISL pre-steps, and
upload/download gating through the shared `repro.core.staleness`
transitions — but with the aggregation transition inlined
(`aggregate_step(collect="hist")`) instead of dropping to host, because a
sweep tracks the *protocol* trajectory (versions, staleness histograms,
idleness — everything `SimResult` carries except accuracy): models are
not trained, which is also what makes whole runs vmappable. Each
variant's outcome is bit-identical to its sequential
`SimulationEngine.run()` — the lockstep property tests and the
`sweep_scaling` benchmark gate enforce it.

What is sweepable: any engine whose scheduler `device_plan` is valid for
the rest of the run (``horizon=None`` — sync/async/fedbuff/periodic/
intra_plane/isl_async), with base protocol steps and no early-stop
target. FedSpace replans mid-run against training status, so it is
inherently sequential — `sweep_engines` raises a clear error rather than
silently diverging (run those variants via `.run()` alongside, as
`examples/fault_study.py` does).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as FT
from repro.core import isl as ISL
from repro.core import staleness as SS
from repro.fl.engine import SimResult, SimulationEngine, _sink_gate


@dataclass
class SweepOutcome:
    """One variant's outcome: the protocol-level `SimResult` (accuracy
    empty — sweeps do not train models) plus host mirrors of the final
    per-satellite state, matching `SimulationEngine`'s properties."""
    result: SimResult
    version: np.ndarray
    pending: np.ndarray
    buffered: np.ndarray
    ig: int


def _not_sweepable(eng, why: str) -> ValueError:
    return ValueError(
        f"scheduler '{eng.scheduler.name}' is not sweepable: {why} — "
        "run this variant sequentially via SimulationEngine.run()")


def _sweep_run(cols, *, indicator, isl_mode, s_max):
    """One variant's full trajectory, pure jnp (vmapped over variants by
    `_run_group`). `cols` carries the per-variant arrays; scheduler kind,
    ISL mode, and the optional-column layout are static per group."""
    W, K = cols["C"].shape
    linked = "grant" in cols
    state = SS.bootstrap_state(K, progress=linked,
                               relay=isl_mode == "sink")
    xs = {"t": jnp.arange(W), "conn": cols["C"]}
    for k in ("grant", "revive", "alive", "sink", "need_hops"):
        if k in cols:
            xs[k] = cols[k]

    def body(carry, inp):
        st, ig, total, idle, hist, nagg = carry
        t, conn = inp["t"], inp["conn"]
        gate = None if not linked else SS.LinkGate(
            inp["grant"], cols["need_up"], cols["need_dn"])
        stf = st if "revive" not in cols \
            else FT.fault_reset(st, inp["revive"])
        alive = inp["alive"] if "alive" in cols else None
        if isl_mode == "sink":
            sink = inp["sink"]
            st2, arrived = ISL.relay_step(stf, inp["need_hops"])
            up_conn = ISL.sink_connectivity(conn, sink, arrived,
                                            st2.pending)
            if alive is not None:
                up_conn = up_conn & alive
            gate = _sink_gate(gate, sink)
            up_st, info = SS.upload_step(st2, ig, up_conn, gate)
            dn_conn = ISL.sink_connectivity(conn, sink, arrived,
                                            up_st.pending)
            if alive is not None:
                dn_conn = dn_conn & alive
        elif isl_mode == "gossip":
            period = cols["period"]
            do_hop = (period <= 1) | (t % period == 0)
            st2, _ = ISL.gossip_step(stf, cols["nxt"], cols["prv"],
                                     cols["left"], cols["right"], do_hop,
                                     alive=alive)
            up_st, info = SS.upload_step(st2, ig, conn, gate)
            dn_conn = conn
        else:
            up_st, info = SS.upload_step(stf, ig, conn, gate)
            dn_conn = conn
        n_buf = info["n_buffered"]
        a = indicator(t, n_buf, cols["args"]) & (n_buf > 0)
        # the engine drops to host here to train and aggregate; the sweep
        # inlines the same transition — aggregate_step's hist/count
        # diagnostics are exactly the engine's host-side bookkeeping
        ag_st, new_ig, agg = SS.aggregate_step(up_st, ig, a, s_max=s_max,
                                               collect="hist")
        dl_st, dn = SS.download_step(ag_st, new_ig, dn_conn, gate)
        if isl_mode == "sink":
            dl_st = ISL.reset_relay(dl_st, dn["downloads"])
        carry = (dl_st, new_ig, total + info["n_connected"],
                 idle + info["n_idle"], hist + agg["hist"],
                 nagg + agg["n_aggregated"])
        return carry, ()

    zero = jnp.int32(0)
    (state, ig, total, idle, hist, nagg), _ = jax.lax.scan(
        body, (state, zero, zero, zero,
               jnp.zeros(s_max + 1, jnp.int32), zero), xs)
    return {"version": state.version, "pending": state.pending,
            "buffered": state.buffered, "ig": ig, "total": total,
            "idle": idle, "hist": hist, "nagg": nagg}


@functools.partial(jax.jit, static_argnames=("indicator", "isl_mode",
                                             "s_max"))
def _run_group(cols, *, indicator, isl_mode, s_max):
    return jax.vmap(functools.partial(_sweep_run, indicator=indicator,
                                      isl_mode=isl_mode, s_max=s_max)
                    )(cols)


def _variant_columns(eng: SimulationEngine):
    """Resolve one engine into (static group signature, per-variant column
    dict) — mirroring exactly what `SimulationEngine.prepare()` would
    execute — or raise for inherently sequential variants."""
    if any(getattr(type(eng), m) is not getattr(SimulationEngine, m)
           for m in ("on_uploads", "on_decide", "on_aggregate",
                     "on_downloads")):
        raise _not_sweepable(eng, "subclassed protocol steps")
    cfg = eng.config
    if cfg.target_acc is not None and cfg.stop_at_target:
        raise _not_sweepable(
            eng, "stop-at-target runs end at a training-dependent window")
    W, K = eng.num_windows, eng.K
    sched = eng.scheduler
    mode = getattr(sched, "isl_mode", None)
    isl_rt = eng.isl if (eng.isl is not None and mode is not None) \
        else None
    mode = mode if isl_rt is not None else None
    sched.isl = isl_rt
    sched.mesh = None
    sched.reset()

    linked = eng.link_budget is not None
    state0 = SS.bootstrap_state(K, progress=linked, relay=mode == "sink")
    extra = {} if eng._trace is None else {
        "exec_connectivity": eng.C,
        "exec_link": None if not linked else SS.LinkGate(
            eng._grants, int(eng.link_budget.need_up),
            int(eng.link_budget.need_dn))}
    plan_link = None if not linked else SS.LinkGate(
        eng._plan_grants, int(eng.link_budget.need_up),
        int(eng.link_budget.need_dn))
    plan = sched.device_plan(0, K=K, state=state0, ig=0,
                             connectivity=eng._plan_C, status=0.0,
                             link=plan_link, **extra)
    if plan is None:
        raise _not_sweepable(eng, "no device plan")
    fn, args, horizon = plan
    if horizon is not None:
        raise _not_sweepable(
            eng, "its device plan replans mid-run (finite horizon)")

    cols = {"C": np.asarray(eng.C[:W], bool), "args": args}
    if linked:
        cols["grant"] = np.asarray(eng._grants[:W], np.int32)
        cols["need_up"] = np.int32(eng.link_budget.need_up)
        cols["need_dn"] = np.int32(eng.link_budget.need_dn)
    if eng._trace is not None:
        cols["revive"] = np.asarray(eng._trace.revive[:W], bool)
        cols["alive"] = np.asarray(eng._trace.alive[:W], bool)
    if mode == "sink":
        # expand the per-epoch elections into per-window rows (the engine
        # clips scan chunks to epochs instead; the sweep scans all W)
        ep = isl_rt.epoch
        sink = np.empty((W, K), np.int32)
        need = np.empty((W, K), np.int32)
        alive_rows = None if eng._trace is None \
            else np.asarray(eng._trace.alive[:W], bool)
        for e0 in range(0, W, ep):
            e1 = min(e0 + ep, W)
            alive_e = None if alive_rows is None \
                else alive_rows[e0:e1].any(axis=0)
            s, n = isl_rt.sink_plan(eng.C[e0:e1], alive=alive_e)
            sink[e0:e1] = np.asarray(s, np.int32)
            need[e0:e1] = np.asarray(n, np.int32)
        cols["sink"], cols["need_hops"] = sink, need
    elif mode == "gossip":
        topo = isl_rt.topology
        idx = np.arange(K, dtype=np.int32)
        cross = isl_rt.cross_plane
        cols["nxt"] = np.asarray(topo.nxt, np.int32)
        cols["prv"] = np.asarray(topo.prv, np.int32)
        cols["left"] = np.asarray(topo.left, np.int32) if cross else idx
        cols["right"] = np.asarray(topo.right, np.int32) if cross else idx
        cols["period"] = np.int32(max(isl_rt.relay_windows, 1))

    leaves = jax.tree.leaves(args)
    args_sig = (jax.tree.structure(args),
                tuple((jnp.asarray(x).shape, str(jnp.asarray(x).dtype))
                      for x in leaves))
    key = (fn, mode, W, K, cfg.s_max, linked, eng._trace is not None,
           args_sig)
    return key, cols


def sweep_engines(engines: Sequence[SimulationEngine]
                  ) -> List[SweepOutcome]:
    """Run every engine's full protocol trajectory in batched dispatches.

    Engines are grouped by static shape — scheduler indicator, ISL mode,
    horizon, K, and which optional columns (link grants, fault masks) they
    carry — and each group runs as one `jit(vmap)` call; a 32-variant
    fedbuff×faults grid is one dispatch. Outcomes come back in input
    order, each bit-identical to that engine's own `run()` (protocol
    counters and final state; `accuracy` is empty — sweeps do not train).

    Raises ValueError for inherently sequential variants (FedSpace's
    replanning, subclassed steps, stop-at-target runs).
    """
    keyed = [_variant_columns(e) for e in engines]
    groups = {}
    for i, (key, cols) in enumerate(keyed):
        groups.setdefault(key, []).append((i, cols))

    outcomes: List[SweepOutcome] = [None] * len(engines)
    for (fn, mode, W, K, s_max, *_rest), members in groups.items():
        batched = jax.tree.map(
            lambda *xs: jnp.asarray(np.stack(xs)),
            *[cols for _, cols in members])
        out = _run_group(batched, indicator=fn, isl_mode=mode,
                         s_max=s_max)
        out = jax.tree.map(np.asarray, out)
        for v, (i, _) in enumerate(members):
            eng = engines[i]
            res = SimResult(scheme=eng.scheduler.name,
                            target_acc=eng.config.target_acc)
            res.staleness_hist = out["hist"][v].astype(np.int64)
            res.idle_connections = int(out["idle"][v])
            res.total_connections = int(out["total"][v])
            res.num_global_updates = int(out["ig"][v])
            res.num_aggregated_gradients = int(out["nagg"][v])
            res.windows_run = W
            outcomes[i] = SweepOutcome(
                result=res, version=out["version"][v],
                pending=out["pending"][v], buffered=out["buffered"][v],
                ig=int(out["ig"][v]))
    return outcomes


def run_sweep(worlds: Sequence) -> List[SimResult]:
    """Batched counterpart of ``[w.run() for w in worlds]`` over
    `Federation` variants (`with_scheduler`/`with_faults` clones or any
    mix): builds each world's engine, dispatches them through
    `sweep_engines`, and returns the per-variant `SimResult`s in input
    order."""
    return [o.result for o in
            sweep_engines([w.engine() for w in worlds])]
