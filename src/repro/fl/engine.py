"""Event-driven FL simulation engine over the connectivity sequence
(Algorithm 1), decomposed into overridable protocol steps.

Time advances in T0 windows (15 min each). At window i the GS:
  receives pending updates from connected satellites (`on_uploads`), asks
  the scheduler whether to aggregate a^i (`on_decide`), applies the
  staleness-compensated update of eq. 4 when a^i = 1 (`on_aggregate`), and
  broadcasts the current model (`on_downloads`).

The engine mirrors exactly the protocol the schedule-search simulator
(repro.core.staleness) assumes, with real gradients; the per-satellite
integer state is the same SatState, so FedSpaceScheduler reads it directly.

Subclass and override a step to model protocol variants (ISL propagation,
sink satellites, lossy links); attach `repro.fl.callbacks.Callback`s for
cross-cutting concerns (metric streaming, checkpointing, early stop).
`repro.fl.simulation.run_simulation` is a thin back-compat wrapper.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointStore
from repro.core import staleness as SS
from repro.core.aggregation import aggregation_weights
from repro.core.scheduler import Scheduler
from repro.fl.client import make_batched_client_update, make_client_update
from repro.kernels.agg.ops import aggregate_params_tree

T0_MINUTES = 15.0


@dataclass
class SimResult:
    scheme: str
    accuracy: List[float] = field(default_factory=list)
    val_loss: List[float] = field(default_factory=list)
    eval_windows: List[int] = field(default_factory=list)
    staleness_hist: np.ndarray = None
    idle_connections: int = 0
    total_connections: int = 0
    num_global_updates: int = 0
    num_aggregated_gradients: int = 0
    windows_run: int = 0
    time_to_target_days: Optional[float] = None
    target_acc: Optional[float] = None

    def days(self, window: int) -> float:
        return window * T0_MINUTES / 60.0 / 24.0

    def summary(self) -> dict:
        return {
            "scheme": self.scheme,
            "final_acc": self.accuracy[-1] if self.accuracy else None,
            "best_acc": max(self.accuracy) if self.accuracy else None,
            "time_to_target_days": self.time_to_target_days,
            "global_updates": self.num_global_updates,
            "aggregated_gradients": self.num_aggregated_gradients,
            "idle_connections": self.idle_connections,
            "total_connections": self.total_connections,
            "staleness_hist": (self.staleness_hist.tolist()
                               if self.staleness_hist is not None else None),
        }


@dataclass
class EngineConfig:
    """Protocol/training knobs of one simulated run (the former
    `run_simulation` keyword soup, as data)."""
    local_steps: int = 4
    batch_size: int = 32
    client_lr: float = 0.05
    server_lr: float = 1.0
    alpha: float = 0.5
    eval_every: int = 8
    target_acc: Optional[float] = None
    max_windows: Optional[int] = None
    repeat_connectivity: int = 1   # 0: auto-tile C to cover max_windows
    s_max: int = 8
    # None = unset: lets experiment-level settings (FLExperiment.seed,
    # LinkConfig.uplink_topk) apply without 0 doubling as a sentinel
    seed: Optional[int] = None           # unset -> 0
    stop_at_target: bool = True
    uplink_topk: Optional[float] = None  # >0: compressed uplink; unset -> 0


class SimulationEngine:
    """One federated run: connectivity x adapter x scheduler -> SimResult.

    Protocol steps (`on_uploads`, `on_decide`, `on_aggregate`,
    `on_downloads`) are methods so scenario variants override exactly the
    step they change; callbacks observe the run without touching it.
    """

    def __init__(self, C: np.ndarray, adapter, scheduler: Scheduler,
                 config: Optional[EngineConfig] = None, *,
                 callbacks: Sequence = (), init_params=None, **overrides):
        cfg = config if config is not None else EngineConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        cfg = dataclasses.replace(
            cfg, seed=0 if cfg.seed is None else cfg.seed,
            uplink_topk=(0.0 if cfg.uplink_topk is None
                         else cfg.uplink_topk))
        self.config = cfg
        repeat = cfg.repeat_connectivity
        if repeat == 0:    # auto: tile C up to the requested horizon
            need = cfg.max_windows or C.shape[0]
            repeat = max(1, -(-int(need) // C.shape[0]))
        if repeat > 1:
            C = np.concatenate([C] * repeat, axis=0)
        self.C = np.asarray(C, bool)
        self.adapter = adapter
        self.scheduler = scheduler
        self.callbacks = list(callbacks)
        self._init_params = init_params
        self._stop_requested = False

        self.num_windows = self.C.shape[0]
        if cfg.max_windows:
            self.num_windows = min(self.num_windows, cfg.max_windows)
        self.K = self.C.shape[1]

    # ------------------------------------------------------------------ API

    def request_stop(self) -> None:
        """Ask the engine to stop after the current window (callbacks use
        this for early stopping)."""
        self._stop_requested = True

    def prepare(self) -> None:
        """Initialize run state (model, client-update programs, checkpoint
        store, per-satellite protocol arrays). `run` calls this; benchmarks
        and tests call it directly to drive individual protocol steps."""
        cfg = self.config
        self.scheduler.reset()
        self._stop_requested = False

        key = jax.random.PRNGKey(cfg.seed)
        self.params = (self.adapter.init(key) if self._init_params is None
                       else self._init_params)
        mask = self.adapter.trainable_mask(self.params) \
            if hasattr(self.adapter, "trainable_mask") else None
        self._client_update = make_client_update(
            self.adapter, local_steps=cfg.local_steps, lr=cfg.client_lr,
            trainable_mask=mask)
        self._batched_update = make_batched_client_update(
            self.adapter, local_steps=cfg.local_steps, lr=cfg.client_lr,
            trainable_mask=mask, uplink_topk=cfg.uplink_topk)

        self.store = CheckpointStore(keep_in_memory=cfg.s_max + 26)
        self.store.put(0, self.params)
        self.ig = 0
        self.version = np.zeros(self.K, np.int64)   # model each sat holds
        self.pending = np.zeros(self.K, np.int64)   # base of pending update
        self.buffered_base = np.full(self.K, -1, np.int64)

        self.result = SimResult(scheme=self.scheduler.name,
                                target_acc=cfg.target_acc)
        self.result.staleness_hist = np.zeros(cfg.s_max + 1, np.int64)
        self.status = float(self.adapter.val_loss(self.params))

    def run(self) -> SimResult:
        cfg = self.config
        self.prepare()
        try:
            self._emit("on_run_begin")
            for i in range(self.num_windows):
                conn = self.C[i]
                n_buf = self.on_uploads(i, conn)
                a = self.on_decide(i, n_buf)
                if a and n_buf > 0:
                    self.on_aggregate(i)
                self.on_downloads(i, conn)
                self.result.windows_run = i + 1
                stop = False
                if (i + 1) % cfg.eval_every == 0 \
                        or i == self.num_windows - 1:
                    stop = self.evaluate(i)
                self._emit("on_window_end", i)
                if stop or self._stop_requested:
                    break
        finally:
            # always emitted (even on a mid-run exception) so callbacks
            # holding resources — open files, sockets — can release them
            self._emit("on_run_end", self.result)
        return self.result

    # -------------------------------------------------------- protocol steps

    def on_uploads(self, i: int, conn: np.ndarray) -> int:
        """Connected satellites hand their pending update to the GS buffer.
        Returns the buffer occupancy. Vectorized over the constellation."""
        res = self.result
        res.total_connections += int(conn.sum())
        has_pending = conn & (self.pending >= 0)
        # idle contact: nothing to upload and model already current
        res.idle_connections += int(
            (conn & ~has_pending & (self.version == self.ig)).sum())
        self.buffered_base[has_pending] = self.pending[has_pending]
        self.pending[has_pending] = -1
        return int((self.buffered_base >= 0).sum())

    def on_decide(self, i: int, n_buf: int) -> bool:
        """Ask the scheduler for the aggregation indicator a^i."""
        state = SS.SatState(jnp.asarray(self.version, jnp.int32),
                            jnp.asarray(self.pending, jnp.int32),
                            jnp.asarray(self.buffered_base, jnp.int32))
        return self.scheduler.decide(
            i, n_in_buffer=n_buf, K=self.K, state=state, ig=self.ig,
            connectivity=self.C, status=self.status)

    def on_aggregate(self, i: int) -> None:
        """Apply the staleness-compensated buffered update (eq. 4).

        Client training is batched: buffered satellites are grouped by base
        model version (and batch shape), each group trains under one
        vmapped jitted call — with the optional uplink compression fused in
        (see `make_batched_client_update`) — instead of one dispatch plus
        checkpoint fetch per satellite. The weighted reduction then routes
        through the aggregation kernel (`aggregate_params_tree`: Pallas on
        TPU, bit-identical jnp elsewhere). Per-satellite updates are
        bit-identical to the sequential path, so trajectories match the
        seed engine exactly.
        """
        cfg = self.config
        ks = np.flatnonzero(self.buffered_base >= 0)
        stal = self.ig - self.buffered_base[ks]
        stack = self._train_buffered(ks, round_rng=i)
        w = aggregation_weights(jnp.asarray(stal), cfg.alpha) \
            * cfg.server_lr
        self.params = aggregate_params_tree(self.params, stack, w)
        self.ig += 1
        self.store.put(self.ig, self.params)
        refs = np.concatenate([self.pending, self.buffered_base])
        refs = refs[refs >= 0]
        self.store.prune(int(refs.min()) if refs.size else self.ig)
        res = self.result
        res.num_global_updates += 1
        res.num_aggregated_gradients += len(ks)
        np.add.at(res.staleness_hist, np.clip(stal, 0, cfg.s_max), 1)
        self.buffered_base[:] = -1
        self._emit("on_aggregate_end", i,
                   {"ig": self.ig, "n_aggregated": len(ks),
                    "staleness": stal.tolist()})

    def _train_buffered(self, ks: np.ndarray, *, round_rng: int):
        """Compute the buffered satellites' updates, batched by base model
        version. Returns the update stack (leading dim len(ks)) in `ks`
        order, matching the staleness vector.

        Per base version: one checkpoint fetch, one batched data gather
        (`adapter.client_batch_many` when available — a single host gather
        + device transfer), one vmapped jitted training call. Satellites
        the batched gather can't serve (empty shards, off-modal batch
        widths) fall back to per-satellite batches, grouped by shape."""
        cfg = self.config
        by_base = {}   # base version -> [(row in ks, client id)]
        for row, k in enumerate(ks):
            by_base.setdefault(int(self.buffered_base[k]),
                               []).append((row, int(k)))
        many = getattr(self.adapter, "client_batch_many", None)
        order, chunks, zero_rows = [], [], []
        for base_v, members in by_base.items():
            base = self.store.get(base_v)       # fetched once per group
            rest = range(len(members))
            if many is not None:
                stacked, used = many([k for _, k in members], round_rng,
                                     cfg.batch_size, cfg.local_steps)
                if used:
                    chunks.append(self._run_batched(base, stacked,
                                                    len(used)))
                    order += [members[u][0] for u in used]
                    rest = [j for j in rest if j not in set(used)]
            by_shape = {}  # leftovers / no batched gather: group by shape
            for j in rest:
                row, k = members[j]
                batch = self.adapter.client_batch(k, round_rng,
                                                  cfg.batch_size,
                                                  cfg.local_steps)
                if batch is None:
                    zero_rows.append(row)
                    continue
                sig = tuple(tuple(leaf.shape)
                            for leaf in jax.tree.leaves(batch))
                by_shape.setdefault(sig, []).append((row, batch))
            for mem in by_shape.values():
                batches = jax.tree.map(lambda *bs: jnp.stack(bs),
                                       *[b for _, b in mem])
                chunks.append(self._run_batched(base, batches, len(mem)))
                order += [row for row, _ in mem]
        if zero_rows:
            chunks.append(jax.tree.map(
                lambda p: jnp.zeros((len(zero_rows),) + p.shape, p.dtype),
                self.params))
            order += zero_rows
        inv = np.argsort(np.asarray(order))     # back to ks order
        return jax.tree.map(lambda *cs: jnp.concatenate(cs, axis=0)[inv],
                            *chunks)

    def _run_batched(self, base, batches, m: int):
        """Run the vmapped client-update program on a group of m
        satellites, padded to the next power of two (repeating row 0) so
        the jitted program compiles O(log K) distinct batch sizes over a
        run instead of one per observed group size. Rows are independent
        under vmap, so the real rows are unaffected by padding."""
        bucket = 1 << (m - 1).bit_length()
        if bucket == m:
            return self._batched_update(base, batches)
        batches = jax.tree.map(
            lambda b: jnp.concatenate(
                [b, jnp.broadcast_to(b[:1], (bucket - m,) + b.shape[1:])],
                axis=0), batches)
        return jax.tree.map(lambda u: u[:m],
                            self._batched_update(base, batches))

    def on_downloads(self, i: int, conn: np.ndarray) -> None:
        """Connected satellites fetch the current global model and start a
        fresh local round on it. Vectorized over the constellation."""
        behind = conn & (self.version < self.ig)
        self.version[behind] = self.ig
        self.pending[behind] = self.ig

    # --------------------------------------------------------------- eval

    def evaluate(self, i: int) -> bool:
        """Eval checkpoint; returns True when the run should stop (target
        accuracy reached and stop_at_target is set)."""
        cfg, res = self.config, self.result
        acc = self.adapter.accuracy(self.params)
        self.status = float(self.adapter.val_loss(self.params))
        res.accuracy.append(acc)
        res.val_loss.append(self.status)
        res.eval_windows.append(i)
        self._emit("on_eval", i, {
            "window": i, "day": res.days(i), "accuracy": acc,
            "val_loss": self.status,
            "global_updates": res.num_global_updates,
            "aggregated_gradients": res.num_aggregated_gradients,
        })
        if (cfg.target_acc is not None and acc >= cfg.target_acc
                and res.time_to_target_days is None):
            res.time_to_target_days = res.days(i)
            if cfg.stop_at_target:
                return True
        return False

    # ------------------------------------------------------------ callbacks

    def _emit(self, event: str, *args) -> None:
        for cb in self.callbacks:
            handler = getattr(cb, event, None)
            if handler is not None:
                handler(self, *args)
