"""Event-driven FL simulation engine over the connectivity sequence
(Algorithm 1), decomposed into overridable protocol steps.

Time advances in T0 windows (15 min each). At window i the GS:
  receives pending updates from connected satellites (`on_uploads`), asks
  the scheduler whether to aggregate a^i (`on_decide`), applies the
  staleness-compensated update of eq. 4 when a^i = 1 (`on_aggregate`), and
  broadcasts the current model (`on_downloads`).

The per-satellite protocol state is the device-resident
`repro.core.staleness.SatState`, advanced through the SAME jitted
sub-transitions (`upload_step` / `aggregate_step` / `download_step`) the
schedule-search simulator scans — one Algorithm-1 implementation shared by
the engine, the search, and the utility sampler. The former numpy arrays
(`version` / `pending` / `buffered_base`) survive as read-only host
mirrors, materialized only at diagnostic points.

Two execution strategies, same trajectory bit-for-bit:
  * fast loop (default): when no protocol step is overridden and the
    scheduler provides `device_plan`, windows run in chunked jitted scans
    (`_scan_windows`) that stop at the first aggregation event — per-window
    Python dispatch and device→host transfers disappear from the hot loop;
  * host loop: per-window `on_uploads`/`on_decide`/`on_aggregate`/
    `on_downloads` calls through the same transitions, taken automatically
    for subclassed steps or schedulers without a device plan.

Finite link budgets (`repro.core.connectivity.LinkBudget`, built by the
`Federation` layer from `LinkConfig`) slot into the same transitions: the
engine then runs on capacity-resolved effective connectivity and gates
every upload/download on accumulated per-window transfer grants, under
both execution strategies.

Subclass and override a step to model protocol variants (ISL propagation,
sink satellites, lossy links); attach `repro.fl.callbacks.Callback`s for
cross-cutting concerns (metric streaming, checkpointing, early stop).
`repro.fl.simulation.run_simulation` is a thin back-compat wrapper.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import DeviceCheckpointStore
from repro.core import faults as FT
from repro.core import isl as ISL
from repro.core import mesh as MM
from repro.core import staleness as SS
from repro.core.aggregation import aggregation_weights
from repro.core.scheduler import Scheduler
from repro.fl.client import make_batched_client_update, make_client_update
from repro.kernels.agg.ops import aggregate_params_tree

T0_MINUTES = 15.0

# Upper bound on windows per jitted scan: chunks are bucketed to powers of
# two up to this, so the scan compiles O(log) shapes per scheduler kind.
_MAX_CHUNK = 128


# ---------------------------------------------------------------------------
# jitted protocol-transition wrappers (shared by both execution strategies)


@jax.jit
def _upload(state, ig, conn, gate):
    state, info = SS.upload_step(state, ig, conn, gate)
    return state, jnp.stack([info["n_connected"], info["n_idle"],
                             info["n_buffered"]])


@functools.partial(jax.jit, static_argnames=("s_max",))
def _aggregate_state(state, ig, *, s_max):
    # collect="none": the engine computes its own staleness bookkeeping on
    # host in `on_aggregate`, so the per-step histogram never enters the
    # compiled program at all
    state, _, _ = SS.aggregate_step(state, ig, jnp.bool_(True), s_max=s_max,
                                    collect="none")
    return state


@jax.jit
def _download(state, ig, conn, gate):
    state, _ = SS.download_step(state, ig, conn, gate)
    return state


def _sink_gate(gate, sink, axis_name=None):
    """Gather the link gate at each satellite's sink: the plane's shared
    transfer rides the sink's contact units (None passes through). `sink`
    holds global indices, so a sharded satellite axis (`axis_name`)
    gathers the full grant row first."""
    if gate is None:
        return None
    grant = gate.grant
    if axis_name is not None:
        grant = jax.lax.all_gather(grant, axis_name, tiled=True)
    return gate._replace(grant=grant[..., sink])


@jax.jit
def _isl_upload(state, ig, conn, gate, sink, need, alive=None):
    """Sink-relay upload transition (host loop): advance the ring relay
    one window, then run the shared `upload_step` on sink-indexed
    effective connectivity — a member uploads once its update has hopped
    to its plane's sink and the sink has a (served, grant-sufficient)
    contact. `alive` (fault runs) removes dead satellites from the
    sink-routed path — a dead member must not ride its sink's contact."""
    state, arrived = ISL.relay_step(state, need)
    eff = ISL.sink_connectivity(conn, sink, arrived, state.pending)
    if alive is not None:
        eff = eff & alive
    state, info = SS.upload_step(state, ig, eff, _sink_gate(gate, sink))
    return state, jnp.stack([info["n_connected"], info["n_idle"],
                             info["n_buffered"]])


@jax.jit
def _isl_download(state, ig, conn, gate, sink, need, alive=None):
    """Sink-relay download transition (host loop): the plane fetches the
    global model through the sink's contact (no relay advance — uploads
    advanced it this window already); satellites starting a fresh round
    reset their relay counter."""
    arrived = state.relay >= need
    eff = ISL.sink_connectivity(conn, sink, arrived, state.pending)
    if alive is not None:
        eff = eff & alive
    state, dn = SS.download_step(state, ig, eff, _sink_gate(gate, sink))
    return ISL.reset_relay(state, dn["downloads"])


@jax.jit
def _gossip(state, nxt, prv, left, right, do_hop, alive=None):
    state, _ = ISL.gossip_step(state, nxt, prv, left, right, do_hop,
                               alive=alive)
    return state


_fault_reset = jax.jit(FT.fault_reset)


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _scan_impl(state, ig, C_dev, i0, n_valid, ind_args, link_dev,
               isl_dev=None, faults_dev=None, *, indicator, horizon,
               isl_mode=None, axis=None):
    """Advance the protocol over up to `horizon` windows starting at
    absolute window i0, freezing at the first window whose aggregation
    indicator fires (post-upload, pre-aggregation — the engine trains and
    aggregates on host, then resumes). `ig` is constant throughout: no
    aggregation happens inside the scan. Windows at offset >= n_valid are
    padding (bucketed horizon) and leave the state untouched.

    `link_dev` is None (instantaneous transfers) or ``(G_dev, need_up,
    need_dn)`` — the padded device grants matrix plus unit needs — in which
    case the scanned upload/download transitions are gated per window
    through the shared `repro.core.staleness.LinkGate` semantics.

    `isl_mode`/`isl_dev` thread the ISL transitions (`repro.core.isl`)
    into the same scan: ``"sink"`` takes ``(sink, need_hops)`` — one
    election, valid for the whole chunk (the engine clips chunks to
    election epochs) — and runs relay advance + sink-indexed effective
    connectivity around the shared transitions; ``"gossip"`` takes
    ``(nxt, prv, left, right, period)`` and applies the neighbour
    version-exchange before each window's upload. ``None`` (the default)
    compiles the exact ground-only program of previous releases.

    `faults_dev` is None (no fault injection — the exact prior program)
    or ``(revive_dev, alive_dev)`` padded device masks: each window first
    applies `repro.core.faults.fault_reset` to reviving satellites (forced
    re-download on re-entry), and the alive mask additionally gates the
    ISL paths (dead satellites neither gossip nor ride their sink's
    contact — plain connectivity is already masked in `C_dev` by the
    engine).

    `axis` names the mesh axis when the satellite dimension of every
    array here is a shard (`_scan_windows` wraps this body in
    `shard_map`): the transition counters become exact integer psums and
    the ISL sink/neighbour lookups gather the one (K,) row they index —
    everything else runs embarrassingly parallel over the shard.

    Returns (state, counters (horizon, 4) int32) with per-window
    [n_connected, n_idle, n_buffered, a]; counter rows after the event row
    are garbage the caller must ignore.
    """
    xs = {"t": i0 + jnp.arange(horizon),
          "conn": jax.lax.dynamic_slice_in_dim(C_dev, i0, horizon, axis=0)}
    if link_dev is not None:
        G_dev, need_up, need_dn = link_dev
        xs["grant"] = jax.lax.dynamic_slice_in_dim(G_dev, i0, horizon,
                                                   axis=0)
    if faults_dev is not None:
        R_dev, A_dev = faults_dev
        xs["revive"] = jax.lax.dynamic_slice_in_dim(R_dev, i0, horizon,
                                                    axis=0)
        xs["alive"] = jax.lax.dynamic_slice_in_dim(A_dev, i0, horizon,
                                                   axis=0)

    def body(carry, inp):
        st, done = carry
        t, conn = inp["t"], inp["conn"]
        gate = None if link_dev is None \
            else SS.LinkGate(inp["grant"], need_up, need_dn)
        live = (~done) & (t - i0 < n_valid)
        alive = inp["alive"] if faults_dev is not None else None
        stf = st if faults_dev is None else FT.fault_reset(st,
                                                           inp["revive"])
        if isl_mode == "sink":
            sink, need = isl_dev
            st2, arrived = ISL.relay_step(stf, need)
            up_conn = ISL.sink_connectivity(conn, sink, arrived,
                                            st2.pending, axis_name=axis)
            if alive is not None:
                up_conn = up_conn & alive
            gate = _sink_gate(gate, sink, axis)
            up_st, info = SS.upload_step(st2, ig, up_conn, gate,
                                         axis_name=axis)
            dn_conn = ISL.sink_connectivity(conn, sink, arrived,
                                            up_st.pending, axis_name=axis)
            if alive is not None:
                dn_conn = dn_conn & alive
        elif isl_mode == "gossip":
            g_nxt, g_prv, g_left, g_right, period = isl_dev
            do_hop = (period <= 1) | (t % period == 0)
            st2, _ = ISL.gossip_step(stf, g_nxt, g_prv, g_left, g_right,
                                     do_hop, alive=alive, axis_name=axis)
            up_st, info = SS.upload_step(st2, ig, conn, gate,
                                         axis_name=axis)
            dn_conn = conn
        else:
            up_st, info = SS.upload_step(stf, ig, conn, gate,
                                         axis_name=axis)
            dn_conn = conn
        n_buf = info["n_buffered"]
        a = live & indicator(t, n_buf, ind_args) & (n_buf > 0)
        dl_st, dn = SS.download_step(up_st, ig, dn_conn, gate)
        if isl_mode == "sink":
            dl_st = ISL.reset_relay(dl_st, dn["downloads"])
        new_st = _tree_where(live, _tree_where(a, up_st, dl_st), st)
        counters = jnp.stack([info["n_connected"], info["n_idle"], n_buf,
                              a.astype(jnp.int32)])
        return (new_st, done | a), counters

    (state, _), counters = jax.lax.scan(body, (state, jnp.bool_(False)), xs)
    return state, counters


@functools.partial(jax.jit, static_argnames=("indicator", "horizon",
                                             "isl_mode", "mesh"))
def _scan_windows(state, ig, C_dev, i0, n_valid, ind_args, link_dev,
                  isl_dev=None, faults_dev=None, *, indicator, horizon,
                  isl_mode=None, mesh=None):
    """`_scan_impl`, jitted — and, when `mesh` is given (a
    `jax.sharding.Mesh`, static: meshes hash), wrapped in `shard_map`
    along the satellite axis. Satellite-sized inputs (state columns, the
    connectivity/grant/fault matrices, ISL index arrays) shard; window
    indices, `ig`, the indicator args, and the link needs replicate; the
    counters come back replicated because every cross-shard quantity
    inside is an exact integer psum — so the host-side event loop reads
    identical values from any shard and `mesh=None` compiles the exact
    single-device program of previous releases."""
    impl = functools.partial(_scan_impl, indicator=indicator,
                             horizon=horizon, isl_mode=isl_mode)
    if mesh is None:
        return impl(state, ig, C_dev, i0, n_valid, ind_args, link_dev,
                    isl_dev, faults_dev)
    ax = mesh.axis_names[0]
    P = jax.sharding.PartitionSpec
    sat, rep, col = P(ax), P(), P(None, ax)
    link_spec = rep if link_dev is None else (col, rep, rep)
    if isl_mode == "sink":
        isl_spec = (sat, sat)
    elif isl_mode == "gossip":
        isl_spec = (sat, sat, sat, sat, rep)
    else:
        isl_spec = rep
    faults_spec = rep if faults_dev is None else (col, col)
    sharded = MM.shard_map(
        functools.partial(impl, axis=ax), mesh,
        in_specs=(sat, rep, col, rep, rep, rep, link_spec, isl_spec,
                  faults_spec),
        out_specs=(sat, rep))
    return sharded(state, ig, C_dev, i0, n_valid, ind_args, link_dev,
                   isl_dev, faults_dev)


@dataclass
class SimResult:
    """Outcome of one simulated federated run.

    Fields: `scheme` (scheduler name), `accuracy`/`val_loss`/
    `eval_windows` (one entry per eval checkpoint), `staleness_hist`
    (aggregated-gradient counts per clipped staleness),
    `idle_connections`/`total_connections` (eq.-10 idleness accounting),
    `num_global_updates` (aggregations), `num_aggregated_gradients`,
    `windows_run`, and `time_to_target_days`/`target_acc` when a target
    accuracy was set. `replan_stats` carries the `ReplanService` counters
    (full vs delta replans, invalidation reasons) when the scheduler
    routes eq.-13 searches through one. `days(window)` converts a window
    index to simulated days; `summary()` returns the JSON-friendly
    digest."""
    scheme: str
    accuracy: List[float] = field(default_factory=list)
    val_loss: List[float] = field(default_factory=list)
    eval_windows: List[int] = field(default_factory=list)
    staleness_hist: Optional[np.ndarray] = None
    idle_connections: int = 0
    total_connections: int = 0
    num_global_updates: int = 0
    num_aggregated_gradients: int = 0
    windows_run: int = 0
    time_to_target_days: Optional[float] = None
    target_acc: Optional[float] = None
    replan_stats: Optional[dict] = None

    def days(self, window: int) -> float:
        """Simulated days elapsed at `window` (T0 = 15-minute windows)."""
        return window * T0_MINUTES / 60.0 / 24.0

    def summary(self) -> dict:
        """JSON-friendly digest (final/best accuracy, counters, hist)."""
        return {
            "scheme": self.scheme,
            "final_acc": self.accuracy[-1] if self.accuracy else None,
            "best_acc": max(self.accuracy) if self.accuracy else None,
            "time_to_target_days": self.time_to_target_days,
            "global_updates": self.num_global_updates,
            "aggregated_gradients": self.num_aggregated_gradients,
            "idle_connections": self.idle_connections,
            "total_connections": self.total_connections,
            "staleness_hist": (self.staleness_hist.tolist()
                               if self.staleness_hist is not None else None),
            "replan_stats": self.replan_stats,
        }


@dataclass
class EngineConfig:
    """Protocol/training knobs of one simulated run (the former
    `run_simulation` keyword soup, as data)."""
    local_steps: int = 4
    batch_size: int = 32
    client_lr: float = 0.05
    server_lr: float = 1.0
    alpha: float = 0.5
    eval_every: int = 8
    target_acc: Optional[float] = None
    max_windows: Optional[int] = None
    repeat_connectivity: int = 1   # 0: auto-tile C to cover max_windows
    s_max: int = 8
    # None = unset: lets experiment-level settings (FLExperiment.seed,
    # LinkConfig.uplink_topk) apply without 0 doubling as a sentinel
    seed: Optional[int] = None           # unset -> 0
    stop_at_target: bool = True
    uplink_topk: Optional[float] = None  # >0: compressed uplink; unset -> 0
    # dense int8 uplink quantization (ignored when uplink_topk > 0, whose
    # kept values are already int8); unset -> False / LinkConfig fallback
    uplink_int8: Optional[bool] = None
    # False forces the per-window host loop even when the chunked jitted
    # fast loop would apply — e.g. for callbacks that must observe the
    # device state at every single window boundary
    fast_loop: bool = True

    def __post_init__(self):
        # 0.0 stays legal alongside None: the engine resolves the unset
        # sentinel to 0.0 via dataclasses.replace, which re-runs this hook
        v = self.uplink_topk
        if v is not None and v != 0.0 and not 0.0 < v <= 1.0:
            raise ValueError(
                f"EngineConfig.uplink_topk must be in (0, 1], got {v}")


class RunArtifacts(NamedTuple):
    """The resolved world arrays one run executes on: the effective
    connectivity/grants (`C`/`grants`), the scheduler-facing planning view
    (`plan_C`/`plan_grants` — the same objects unless a blind fault trace
    splits them), and the horizon-extended `FaultTrace`."""
    C: np.ndarray
    grants: Optional[np.ndarray]
    plan_C: np.ndarray
    plan_grants: Optional[np.ndarray]
    trace: Optional[FT.FaultTrace]


def resolve_run_artifacts(C, cfg: EngineConfig, *, link_budget=None,
                          faults=None) -> RunArtifacts:
    """Resolve raw world inputs into `RunArtifacts`: substitute the link
    budget's capacity-resolved `served` matrix, tile the connectivity (and
    grants) to the requested horizon per `cfg.repeat_connectivity`, extend
    the fault trace over the tiled length, and split the plan view from
    the executed view (clean-vs-masked under a blind trace, identical
    under none/oracle). One resolution semantics shared by the engine and
    the batched sweep (`repro.fl.sweep`)."""
    grants = assign = None
    if link_budget is not None:
        C = link_budget.served
        grants = np.asarray(link_budget.grants, np.int32)
        assign = np.asarray(link_budget.assign, np.int32)
    repeat = cfg.repeat_connectivity
    if repeat == 0:    # auto: tile C up to the requested horizon
        need = cfg.max_windows or C.shape[0]
        repeat = max(1, -(-int(need) // C.shape[0]))
    if repeat > 1:
        C = np.concatenate([C] * repeat, axis=0)
        if grants is not None:
            grants = np.concatenate([grants] * repeat, axis=0)
            assign = np.concatenate([assign] * repeat, axis=0)
    C = np.asarray(C, bool)
    # plan view (what schedulers see) vs executed view (what the run
    # applies): the same objects without faults or under an oracle
    # trace, clean-vs-masked under a blind one
    plan_C, plan_grants = C, grants
    trace = None if faults is None else faults.extended(C.shape[0])
    if trace is None:
        exec_C, exec_grants = C, grants
    elif link_budget is not None:
        exec_C, exec_grants = FT.mask_served(C, grants, assign, trace)
    else:
        exec_C = C & trace.mask[:C.shape[0]]
        exec_grants = None
    if trace is not None and trace.oracle:
        plan_C, plan_grants = exec_C, exec_grants
    return RunArtifacts(exec_C, exec_grants, plan_C, plan_grants, trace)


class SimulationEngine:
    """One federated run: connectivity x adapter x scheduler -> SimResult.

    Protocol steps (`on_uploads`, `on_decide`, `on_aggregate`,
    `on_downloads`) are methods so scenario variants override exactly the
    step they change; callbacks observe the run without touching it.

    Execution-strategy selection (both strategies are bit-identical):
      * the chunked **fast loop** (`_scan_windows`) runs when ALL of —
        `EngineConfig.fast_loop` is True (default), no protocol step is
        overridden in a subclass, and `Scheduler.device_plan` returns a
        plan for the current window;
      * otherwise each window goes through the per-window **host loop**
        (`_run_window`) — one `on_uploads`/`on_decide`/`on_aggregate`/
        `on_downloads` cycle per window through the same jitted
        transitions.
    Fast-loop chunks are clipped to eval boundaries (where `status`
    changes), the scheduler's plan horizon, and `_MAX_CHUNK`, then
    bucketed to powers of two so jit compiles O(log) scan shapes.

    Args:
      C: (num_windows, K) bool connectivity matrix (tiled per
        `EngineConfig.repeat_connectivity`).
      adapter: model adapter (init/loss/client_batch/accuracy/val_loss).
      scheduler: aggregation policy (`repro.core.scheduler.Scheduler`).
      config: `EngineConfig`; keyword `overrides` replace single fields.
      callbacks: `repro.fl.callbacks` observers.
      init_params: optional initial global model (default: adapter.init).
      link_budget: optional `repro.core.connectivity.LinkBudget`. When
        given, the engine runs on its capacity-resolved `served` matrix
        (the `C` argument is replaced — schedulers then plan against
        effective connectivity), satellites carry the in-progress-transfer
        column, and every upload/download is gated on accumulated contact
        units through the shared `LinkGate` transitions — in the fast loop
        and the host loop alike. A trivial budget (unlimited capacity,
        zero needs) is bit-identical to `link_budget=None`.
      isl: optional `repro.core.isl.ISL` runtime (topology + hop latency +
        election period, resolved by `Federation.from_experiment` from
        `FLExperiment.isl`). It only takes effect when the scheduler also
        declares an `isl_mode` ("sink": intra-plane relay toward elected
        sink satellites; "gossip": asynchronous neighbour version
        exchange) — ground-only schedulers under the same experiment run
        the unmodified protocol, so with/without-ISL comparisons share one
        world. `isl=None` (default) leaves every code path bit-identical
        to previous releases.
      faults: optional `repro.core.faults.FaultTrace` (resolved by
        `Federation.from_experiment` from `FLExperiment.faults`). The
        engine then *executes* on the fault-masked artifacts — dead
        satellites lose every contact (and ISL participation), grants are
        weather-rescaled, reviving satellites re-enter through
        `fault_reset`'s forced re-download — while schedulers *plan* on
        the clean connectivity/link view unless the trace is `oracle`
        (the blind/oracle split that measures how each policy degrades
        when its plan is wrong). `faults=None` (default) keeps every
        compiled program and trajectory bit-identical to previous
        releases.
      mesh: optional `jax.sharding.Mesh` (see `repro.core.mesh.sim_mesh`)
        sharding the satellite axis of the protocol state and every
        satellite-sized artifact across devices. K is padded up to a
        multiple of the device count with trajectory-inert
        never-connected satellites (`repro.core.mesh.pad_state`), the
        fast loop's window scans run under `shard_map` with exact
        integer psums as the only cross-shard traffic, and the host-side
        mirrors/event path strip the padding — so any mesh run is
        trajectory-bit-identical to `mesh=None` (the default, which
        compiles the exact single-device program of previous releases).
    """

    def __init__(self, C: np.ndarray, adapter, scheduler: Scheduler,
                 config: Optional[EngineConfig] = None, *,
                 callbacks: Sequence = (), init_params=None,
                 link_budget=None, isl=None, faults=None, mesh=None,
                 **overrides):
        cfg = config if config is not None else EngineConfig()
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        cfg = dataclasses.replace(
            cfg, seed=0 if cfg.seed is None else cfg.seed,
            uplink_topk=(0.0 if cfg.uplink_topk is None
                         else cfg.uplink_topk),
            uplink_int8=bool(cfg.uplink_int8))
        self.config = cfg
        self.link_budget = link_budget
        self.isl = isl
        self.faults = faults
        self.mesh = mesh
        art = resolve_run_artifacts(C, cfg, link_budget=link_budget,
                                    faults=faults)
        self.C, self._grants = art.C, art.grants
        self._plan_C, self._plan_grants = art.plan_C, art.plan_grants
        self._trace = art.trace
        self.adapter = adapter
        self.scheduler = scheduler
        self.callbacks = list(callbacks)
        self._init_params = init_params
        self._stop_requested = False

        self.num_windows = self.C.shape[0]
        if cfg.max_windows:
            self.num_windows = min(self.num_windows, cfg.max_windows)
        self.K = self.C.shape[1]

    # ------------------------------------------------------------------ API

    def request_stop(self) -> None:
        """Ask the engine to stop after the current window (callbacks use
        this for early stopping)."""
        self._stop_requested = True

    @property
    def version(self) -> np.ndarray:
        """Host mirror of the last global version each satellite received.
        Read-only diagnostic — the authoritative state is `self.state`
        (mesh padding, when any, is stripped from every mirror)."""
        return np.asarray(self.state.version)[:self.K]

    @property
    def pending(self) -> np.ndarray:
        """Host mirror of each satellite's pending-update base version."""
        return np.asarray(self.state.pending)[:self.K]

    @property
    def buffered_base(self) -> np.ndarray:
        """Host mirror of the GS buffer's per-satellite base versions."""
        return np.asarray(self.state.buffered)[:self.K]

    @property
    def transfer_progress(self):
        """Host mirror of per-satellite in-progress transfer units (None
        unless the run models a link budget)."""
        return None if self.state.progress is None \
            else np.asarray(self.state.progress)[:self.K]

    @property
    def relay_units(self):
        """Host mirror of per-satellite accumulated ISL hop units (None
        unless the run relays through sink satellites)."""
        return None if self.state.relay is None \
            else np.asarray(self.state.relay)[:self.K]

    def prepare(self) -> None:
        """Initialize run state (model, client-update programs, checkpoint
        ring, device-resident protocol state). `run` calls this; benchmarks
        and tests call it directly to drive individual protocol steps."""
        cfg = self.config
        # ISL activates only when BOTH the runtime and a scheduler-declared
        # mode are present; the scheduler reads the runtime (topology) via
        # its `isl` attribute, bound before reset()
        mode = getattr(self.scheduler, "isl_mode", None)
        self._isl = self.isl if (self.isl is not None
                                 and mode is not None) else None
        self._isl_mode = mode if self._isl is not None else None
        self.scheduler.isl = self._isl
        # schedulers that run device-side simulation (fedspace's eq.-13
        # search) shard it over the same mesh as the run
        self.scheduler.mesh = self.mesh
        self.scheduler.reset()
        self._stop_requested = False
        # mesh runs pad K up to a device-count multiple with
        # trajectory-inert never-connected satellites; _Kp is the padded
        # satellite count every device-side artifact uses
        self._Kp = self.K if self.mesh is None \
            else MM.padded_size(self.K, self.mesh)

        key = jax.random.PRNGKey(cfg.seed)
        self.params = (self.adapter.init(key) if self._init_params is None
                       else self._init_params)
        mask = self.adapter.trainable_mask(self.params) \
            if hasattr(self.adapter, "trainable_mask") else None
        self._client_update = make_client_update(
            self.adapter, local_steps=cfg.local_steps, lr=cfg.client_lr,
            trainable_mask=mask)
        self._batched_update = make_batched_client_update(
            self.adapter, local_steps=cfg.local_steps, lr=cfg.client_lr,
            trainable_mask=mask, uplink_topk=cfg.uplink_topk,
            uplink_int8=bool(cfg.uplink_int8))

        self.store = DeviceCheckpointStore(ring=cfg.s_max + 26)
        self.store.put(0, self.params)
        self.ig = 0
        # every satellite holds w^0 with a pending round on it (Alg. 1
        # init); link-budget runs carry the in-progress-transfer column,
        # sink-relay runs the ISL relay column
        linked = self.link_budget is not None
        self.state = SS.bootstrap_state(self.K, progress=linked,
                                        relay=self._isl_mode == "sink")
        if self.mesh is not None:
            self.state = jax.device_put(
                MM.pad_state(self.state, self._Kp),
                MM.sat_sharding(self.mesh))
        if linked:
            b = self.link_budget
            self._need_up = jnp.int32(b.need_up)
            self._need_dn = jnp.int32(b.need_dn)
            # run-level gates handed to schedulers: exec grants drive the
            # run; blind-fault runs plan on the clean grants view
            self._link = SS.LinkGate(self._grants, int(b.need_up),
                                     int(b.need_dn))
            self._plan_link = self._link \
                if self._plan_grants is self._grants \
                else SS.LinkGate(self._plan_grants, int(b.need_up),
                                 int(b.need_dn))
        else:
            self._link = None
            self._plan_link = None
        self._fast_ok = cfg.fast_loop and all(
            getattr(type(self), m) is getattr(SimulationEngine, m)
            for m in ("on_uploads", "on_decide", "on_aggregate",
                      "on_downloads"))
        # device copy of the run's connectivity (and grants), padded with
        # _MAX_CHUNK all-false/zero rows so a bucketed scan slice never
        # clamps (columns padded to _Kp under a mesh)
        self._C_dev = jnp.asarray(np.concatenate(
            [MM.pad_axis(self.C[:self.num_windows], self._Kp),
             np.zeros((_MAX_CHUNK, self._Kp), bool)])) \
            if self._fast_ok else None
        self._link_dev = None
        if self._fast_ok and linked:
            G_dev = jnp.asarray(np.concatenate(
                [MM.pad_axis(self._grants[:self.num_windows], self._Kp),
                 np.zeros((_MAX_CHUNK, self._Kp), np.int32)]))
            self._link_dev = (G_dev, self._need_up, self._need_dn)
        # fault masks: host rows feed the per-window host loop, padded
        # device copies feed the scans (None everywhere without a trace)
        self._faults_dev = None
        if self._trace is None:
            self._alive = self._revive = None
        else:
            self._alive = np.asarray(
                self._trace.alive[:self.num_windows], bool)
            self._revive = np.asarray(
                self._trace.revive[:self.num_windows], bool)
            if self._fast_ok:
                pad = np.zeros((_MAX_CHUNK, self._Kp), bool)
                self._faults_dev = (
                    jnp.asarray(np.concatenate(
                        [MM.pad_axis(self._revive, self._Kp), pad])),
                    jnp.asarray(np.concatenate(
                        [MM.pad_axis(self._alive, self._Kp), pad])))
        # ISL device state: sink elections are cached per epoch (sink
        # mode); the gossip neighbour arrays are run constants — padded
        # satellites are their own (inert) neighbours/sinks
        self._sink_cache = {}
        self._gossip_dev = None
        if self._isl_mode == "gossip":
            topo = self._isl.topology
            idx = np.arange(self._Kp, dtype=np.int32)
            cross = self._isl.cross_plane

            def nbr(a):
                return jnp.asarray(np.concatenate(
                    [np.asarray(a, np.int32), idx[self.K:]]))

            self._gossip_dev = (
                nbr(topo.nxt), nbr(topo.prv),
                nbr(topo.left) if cross else jnp.asarray(idx),
                nbr(topo.right) if cross else jnp.asarray(idx),
                jnp.int32(max(self._isl.relay_windows, 1)))

        self.result = SimResult(scheme=self.scheduler.name,
                                target_acc=cfg.target_acc)
        self.result.staleness_hist = np.zeros(cfg.s_max + 1, np.int64)
        self.status = float(self.adapter.val_loss(self.params))

    def run(self) -> SimResult:
        """Execute the run: `prepare()`, then advance windows under the
        selected strategy until the horizon, a stop request, or the
        target accuracy. Returns the populated `SimResult`."""
        self.prepare()
        try:
            self._emit("on_run_begin")
            i = 0
            while i < self.num_windows:
                chunk = self._fast_chunk_plan(i) if self._fast_ok else None
                if chunk is None:
                    i, stop = self._run_window(i)
                else:
                    i, stop = self._run_chunk(i, *chunk)
                if stop or self._stop_requested:
                    break
        finally:
            service = getattr(self.scheduler, "service", None)
            if service is not None:
                self.result.replan_stats = {
                    "full": service.stats["full"],
                    "delta": service.stats["delta"],
                    "invalidated": dict(service.stats["invalidated"]),
                }
            # always emitted (even on a mid-run exception) so callbacks
            # holding resources — open files, sockets — can release them
            self._emit("on_run_end", self.result)
        return self.result

    # ---------------------------------------------------- host window loop

    def _run_window(self, i: int):
        """One window through the overridable protocol-step methods.
        Returns (next window, stop)."""
        cfg = self.config
        conn = self.C[i]
        n_buf = self.on_uploads(i, conn)
        a = self.on_decide(i, n_buf)
        if a and n_buf > 0:
            self.on_aggregate(i)
        self.on_downloads(i, conn)
        self.result.windows_run = i + 1
        stop = False
        if (i + 1) % cfg.eval_every == 0 or i == self.num_windows - 1:
            stop = self.evaluate(i)
        self._emit("on_window_end", i)
        return i + 1, stop

    # --------------------------------------------------- chunked fast loop

    def _pad_row(self, row, fill=0):
        """Pad a host (K,) row to the mesh-padded satellite count (no-op
        without a mesh)."""
        return MM.pad_axis(row, self._Kp, fill=fill)

    def _plan_state(self):
        """The scheduler-facing (K,) view of the protocol state — mesh
        padding stripped so `device_plan`/`decide` see the world at its
        declared satellite count."""
        if self._Kp == self.K:
            return self.state
        return jax.tree.map(lambda x: x[..., :self.K], self.state)

    def _gate(self, i: int):
        """Device `LinkGate` for window i (None when no link budget)."""
        if self._link is None:
            return None
        return SS.LinkGate(jnp.asarray(self._pad_row(self._grants[i])),
                           self._need_up, self._need_dn)

    def _sink_plan(self, i: int):
        """Device (sink, need_hops) arrays for window i's election epoch,
        elected once per epoch from the run's effective connectivity.
        Mesh-padded satellites are their own zero-distance sinks — their
        connectivity is all-False, so they stay inert."""
        ep = self._isl.epoch
        e = i // ep
        if e not in self._sink_cache:
            alive_e = None if self._alive is None else \
                self._alive[e * ep:(e + 1) * ep].any(axis=0)
            sink, need = self._isl.sink_plan(self.C[e * ep:(e + 1) * ep],
                                             alive=alive_e)
            if self._Kp != self.K:
                sink = np.concatenate(
                    [np.asarray(sink, np.int32),
                     np.arange(self.K, self._Kp, dtype=np.int32)])
                need = self._pad_row(np.asarray(need, np.int32))
            self._sink_cache[e] = (jnp.asarray(sink), jnp.asarray(need))
        return self._sink_cache[e]

    def _fast_chunk_plan(self, i: int):
        """Ask the scheduler for a device-side indicator valid from window
        i; clip the chunk to eval boundaries (where `status` changes) and
        the scan-size bucket cap. Returns (indicator, args, end) or None."""
        if self._trace is not None:
            # reviving satellites re-enter before planning (idempotent —
            # the scan re-applies the same reset at this window)
            self.state = _fault_reset(
                self.state, jnp.asarray(self._pad_row(self._revive[i])))
        extra = {} if self._trace is None else {
            "exec_connectivity": self.C, "exec_link": self._link}
        plan = self.scheduler.device_plan(
            i, K=self.K, state=self._plan_state(), ig=self.ig,
            connectivity=self._plan_C, status=self.status,
            link=self._plan_link, **extra)
        if plan is None:
            return None
        fn, args, horizon = plan
        end = i + (int(horizon) if horizon is not None
                   else self.num_windows - i)
        ev = self.config.eval_every
        end = min(end, self.num_windows, (i // ev + 1) * ev, i + _MAX_CHUNK)
        if self._isl_mode == "sink":
            # one sink election per scan: clip chunks to election epochs
            ep = self._isl.epoch
            end = min(end, (i // ep + 1) * ep)
        return fn, args, end

    def _run_chunk(self, i: int, fn, args, end: int):
        """Advance windows [i, end) through jitted scans, dropping back to
        host exactly at aggregation events. One device→host transfer of the
        per-window counters per scan; protocol ints and model trajectory
        are bit-identical to the per-window loop. Returns (next, stop)."""
        cfg, res = self.config, self.result
        w = i
        while w < end:
            H = end - w
            bucket = 1 << (H - 1).bit_length()
            if self._isl_mode == "sink":
                isl_dev = self._sink_plan(w)
            elif self._isl_mode == "gossip":
                isl_dev = self._gossip_dev
            else:
                isl_dev = None
            prev_state = self.state
            self.state, counters = _scan_windows(
                self.state, jnp.int32(self.ig), self._C_dev, jnp.int32(w),
                jnp.int32(H), args, self._link_dev, isl_dev,
                self._faults_dev, indicator=fn, horizon=bucket,
                isl_mode=self._isl_mode, mesh=self.mesh)
            counters = np.asarray(counters)
            advanced = H
            for j in range(H):
                n_conn, n_idle, _, a = (int(x) for x in counters[j])
                res.total_connections += n_conn
                res.idle_connections += n_idle
                res.windows_run = w + j + 1
                if a:
                    self.on_aggregate(w + j)
                    self.on_downloads(w + j, self.C[w + j])
                stop = False
                if (w + j + 1) % cfg.eval_every == 0 \
                        or w + j == self.num_windows - 1:
                    stop = self.evaluate(w + j)
                self._emit("on_window_end", w + j)
                if stop or self._stop_requested:
                    if not a and j + 1 < H:
                        # a stop mid-chunk: the scan already advanced the
                        # state past this window — replay the prefix (no
                        # event fired in it, so the rescan is an exact
                        # deterministic replay) so the run freezes one
                        # window after the request, not at the chunk end
                        self.state, _ = _scan_windows(
                            prev_state, jnp.int32(self.ig), self._C_dev,
                            jnp.int32(w), jnp.int32(j + 1), args,
                            self._link_dev, isl_dev, self._faults_dev,
                            indicator=fn, horizon=bucket,
                            isl_mode=self._isl_mode, mesh=self.mesh)
                    return w + j + 1, True
                if a:        # scan froze at the event; rescan from w+j+1
                    advanced = j + 1
                    break
            w += advanced
        return w, False

    # -------------------------------------------------------- protocol steps

    def on_uploads(self, i: int, conn: np.ndarray) -> int:
        """Connected satellites hand their pending update to the GS buffer
        (shared `upload_step` transition on device; under an active ISL
        mode the sink-relay or gossip transition composes in front of it,
        identically to the fast loop's scan body). Returns the buffer
        occupancy."""
        res = self.result
        conn_dev = jnp.asarray(self._pad_row(np.asarray(conn, bool)))
        alive = None
        if self._trace is not None:
            self.state = _fault_reset(
                self.state, jnp.asarray(self._pad_row(self._revive[i])))
            alive = jnp.asarray(self._pad_row(self._alive[i]))
        if self._isl_mode == "sink":
            sink, need = self._sink_plan(i)
            self.state, counters = _isl_upload(
                self.state, jnp.int32(self.ig), conn_dev, self._gate(i),
                sink, need, alive)
        else:
            if self._isl_mode == "gossip":
                per = int(self._gossip_dev[4])
                self.state = _gossip(
                    self.state, *self._gossip_dev[:4],
                    jnp.bool_(per <= 1 or i % per == 0), alive)
            self.state, counters = _upload(self.state, jnp.int32(self.ig),
                                           conn_dev, self._gate(i))
        n_conn, n_idle, n_buf = (int(x) for x in np.asarray(counters))
        res.total_connections += n_conn
        res.idle_connections += n_idle
        return n_buf

    def on_decide(self, i: int, n_buf: int) -> bool:
        """Ask the scheduler for the aggregation indicator a^i. The
        device-resident SatState is handed over as-is — no per-window
        host-array rebuild."""
        return self.scheduler.decide(
            i, n_in_buffer=n_buf, K=self.K, state=self._plan_state(),
            ig=self.ig, connectivity=self._plan_C, status=self.status,
            link=self._plan_link)

    def on_aggregate(self, i: int) -> None:
        """Apply the staleness-compensated buffered update (eq. 4).

        Client training is batched: buffered satellites are grouped by base
        model version (and batch shape), each group trains under one
        vmapped jitted call — with the optional uplink compression fused in
        (see `make_batched_client_update`) — instead of one dispatch plus
        checkpoint fetch per satellite. Base checkpoints come out of the
        device ring (`DeviceCheckpointStore`), so no host→device transfer
        per base version; the weighted reduction routes through the
        aggregation kernel (`aggregate_params_tree`: Pallas on TPU,
        bit-identical jnp elsewhere). The buffer contents are materialized
        to host once here — the grouping and data gather are host work.
        """
        cfg = self.config
        buffered = np.asarray(self.state.buffered)
        ks = np.flatnonzero(buffered >= 0)
        stal = (self.ig - buffered[ks]).astype(np.int64)
        stack = self._train_buffered(ks, buffered, round_rng=i)
        w = aggregation_weights(jnp.asarray(stal), cfg.alpha) \
            * cfg.server_lr
        self.params = aggregate_params_tree(self.params, stack, w)
        self.state = _aggregate_state(self.state, jnp.int32(self.ig),
                                      s_max=cfg.s_max)
        self.ig += 1
        self.store.put(self.ig, self.params)
        refs = np.concatenate([np.asarray(self.state.pending), buffered])
        refs = refs[refs >= 0]
        self.store.prune(int(refs.min()) if refs.size else self.ig)
        res = self.result
        res.num_global_updates += 1
        res.num_aggregated_gradients += len(ks)
        np.add.at(res.staleness_hist, np.clip(stal, 0, cfg.s_max), 1)
        self._emit("on_aggregate_end", i,
                   {"ig": self.ig, "n_aggregated": len(ks),
                    "staleness": stal.tolist()})

    def _train_buffered(self, ks: np.ndarray, buffered: np.ndarray, *,
                        round_rng: int):
        """Compute the buffered satellites' updates, batched by base model
        version. Returns the update stack (leading dim len(ks)) in `ks`
        order, matching the staleness vector.

        Per base version: one checkpoint fetch (a device ring gather), one
        batched data gather (`adapter.client_batch_many` when available — a
        single host gather + device transfer), one vmapped jitted training
        call. Satellites the batched gather can't serve (empty shards,
        off-modal batch widths) fall back to per-satellite batches, grouped
        by shape."""
        cfg = self.config
        by_base = {}   # base version -> [(row in ks, client id)]
        for row, k in enumerate(ks):
            by_base.setdefault(int(buffered[k]), []).append((row, int(k)))
        many = getattr(self.adapter, "client_batch_many", None)
        order, chunks, zero_rows = [], [], []
        for base_v, members in by_base.items():
            base = self.store.get(base_v)       # fetched once per group
            rest = range(len(members))
            if many is not None:
                stacked, used = many([k for _, k in members], round_rng,
                                     cfg.batch_size, cfg.local_steps)
                if used:
                    chunks.append(self._run_batched(base, stacked,
                                                    len(used)))
                    order += [members[u][0] for u in used]
                    rest = [j for j in rest if j not in set(used)]
            by_shape = {}  # leftovers / no batched gather: group by shape
            for j in rest:
                row, k = members[j]
                batch = self.adapter.client_batch(k, round_rng,
                                                  cfg.batch_size,
                                                  cfg.local_steps)
                if batch is None:
                    zero_rows.append(row)
                    continue
                sig = tuple(tuple(leaf.shape)
                            for leaf in jax.tree.leaves(batch))
                by_shape.setdefault(sig, []).append((row, batch))
            for mem in by_shape.values():
                batches = jax.tree.map(lambda *bs: jnp.stack(bs),
                                       *[b for _, b in mem])
                chunks.append(self._run_batched(base, batches, len(mem)))
                order += [row for row, _ in mem]
        if zero_rows:
            chunks.append(jax.tree.map(
                lambda p: jnp.zeros((len(zero_rows),) + p.shape, p.dtype),
                self.params))
            order += zero_rows
        inv = np.argsort(np.asarray(order))     # back to ks order
        return jax.tree.map(lambda *cs: jnp.concatenate(cs, axis=0)[inv],
                            *chunks)

    def _run_batched(self, base, batches, m: int):
        """Run the vmapped client-update program on a group of m
        satellites, padded to the next power of two (repeating row 0) so
        the jitted program compiles O(log K) distinct batch sizes over a
        run instead of one per observed group size. Rows are independent
        under vmap, so the real rows are unaffected by padding."""
        bucket = 1 << (m - 1).bit_length()
        if bucket == m:
            return self._batched_update(base, batches)
        batches = jax.tree.map(
            lambda b: jnp.concatenate(
                [b, jnp.broadcast_to(b[:1], (bucket - m,) + b.shape[1:])],
                axis=0), batches)
        return jax.tree.map(lambda u: u[:m],
                            self._batched_update(base, batches))

    def on_downloads(self, i: int, conn: np.ndarray) -> None:
        """Connected satellites fetch the current global model and start a
        fresh local round on it (shared `download_step` transition),
        link-gated on accumulated downlink progress when a budget is
        modeled. Under sink relaying the plane downloads through its
        sink's contact and fresh rounds reset the relay counter (the fast
        loop's scan body does the same at non-event windows)."""
        conn_dev = jnp.asarray(self._pad_row(np.asarray(conn, bool)))
        if self._isl_mode == "sink":
            sink, need = self._sink_plan(i)
            alive = None if self._trace is None \
                else jnp.asarray(self._pad_row(self._alive[i]))
            self.state = _isl_download(self.state, jnp.int32(self.ig),
                                       conn_dev, self._gate(i), sink, need,
                                       alive)
        else:
            self.state = _download(self.state, jnp.int32(self.ig),
                                   conn_dev, self._gate(i))

    # --------------------------------------------------------------- eval

    def evaluate(self, i: int) -> bool:
        """Eval checkpoint; returns True when the run should stop (target
        accuracy reached and stop_at_target is set)."""
        cfg, res = self.config, self.result
        acc = self.adapter.accuracy(self.params)
        self.status = float(self.adapter.val_loss(self.params))
        res.accuracy.append(acc)
        res.val_loss.append(self.status)
        res.eval_windows.append(i)
        self._emit("on_eval", i, {
            "window": i, "day": res.days(i), "accuracy": acc,
            "val_loss": self.status,
            "global_updates": res.num_global_updates,
            "aggregated_gradients": res.num_aggregated_gradients,
        })
        if (cfg.target_acc is not None and acc >= cfg.target_acc
                and res.time_to_target_days is None):
            res.time_to_target_days = res.days(i)
            if cfg.stop_at_target:
                return True
        return False

    # ------------------------------------------------------------ callbacks

    def _emit(self, event: str, *args) -> None:
        for cb in self.callbacks:
            handler = getattr(cb, event, None)
            if handler is not None:
                handler(self, *args)
