"""Pallas TPU kernel: causal / sliding-window flash attention with GQA.

Motivation (see EXPERIMENTS.md §Roofline): the baseline pure-JAX chunked
attention materializes (bq, T) f32 score panels in HBM every chunk — the
dominant memory-roofline term for the train/prefill shapes. This kernel
keeps the running softmax state (m, l, acc) in VMEM scratch and streams
K/V blocks HBM->VMEM once, so score traffic never touches HBM.

Grid: (B, H, nq, nk) — the trailing kv axis is sequential on TPU, so the
VMEM scratch accumulates across kv blocks and flushes to the output on the
last one. Block shapes default to (bq, hd) = (512, model hd) and bk = 512:
VMEM ~ bq*bk f32 scores + 2*bk*hd kv + bq*hd acc ≈ 1.6 MB at hd=128.

GQA: kv-head index = q-head // (H // K) via the BlockSpec index maps.
Masking: causal and sliding-window; fully-masked kv blocks are skipped with
pl.when (zero compute, zero traffic beyond the prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float,
                  nk: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # block-level reachability: does any (q, k) pair in the tile attend?
    reachable = jnp.bool_(True)
    if causal:
        # newest q must be at or after the oldest key
        reachable = jnp.logical_and(reachable,
                                    k_start <= q_start + bq - 1)
    if window > 0:
        # oldest q must still be within the window of the newest key
        reachable = jnp.logical_and(
            reachable, q_start - (k_start + bk - 1) < window)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale      # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                              # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = True):
    """q: (B, H, Sq, hd); k, v: (B, K, Sk, hd); H % K == 0.

    window = 0 means unwindowed. Returns (B, H, Sq, hd).
    """
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    Sk = k.shape[2]
    assert H % K == 0
    G = H // K
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pad_q = (-Sq) % bq
    pad_k = (-Sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // bq
    nk = k.shape[2] // bk
    grid = (B, H, nq, nk)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=hd ** -0.5, nk=nk, seq_len=Sk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Sq]
    return out
