"""jit'd public API for flash attention, in the model's (B, S, H, hd)
layout."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention_bshd(q, k, v, *, causal=True, window=0, bq=512, bk=512,
                         interpret=None):
    """q: (B, S, H, hd); k, v: (B, T, K, hd) — the transformer-stack layout.
    Transposes to (B, H, S, hd) for the kernel.

    Dispatch mirrors `repro.kernels.agg.ops`: with `interpret=None` (the
    default) the compiled Pallas kernel runs on TPU and the pure-jnp
    oracle (`attention_ref`) everywhere else, keeping off-TPU FL runs
    bit-reproducible; an explicit `interpret=True` forces the Pallas
    interpreter (kernel debugging — close to, not bit-identical with, the
    oracle)."""
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if interpret is None:
        if on_tpu():
            interpret = False
        else:
            return jnp.moveaxis(
                attention_ref(qt, kt, vt, causal=causal, window=window),
                1, 2)
    out = flash_attention(qt, kt, vt, causal=causal, window=window, bq=bq,
                          bk=bk, interpret=interpret)
    return jnp.moveaxis(out, 1, 2)
