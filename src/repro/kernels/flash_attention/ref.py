"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, Sq, hd); k, v: (B, K, Sk, hd). Materialized-softmax oracle
    with GQA, causal and sliding-window masking."""
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    Sk = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg, kf) * hd ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)
