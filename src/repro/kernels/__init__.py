"""Pallas TPU kernels for the framework's compute hot spots:

  agg/              staleness-weighted buffered aggregation (paper eq. 4)
  rmsnorm/          RMSNorm over the model dim
  flash_attention/  causal / sliding-window flash attention (GQA)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public wrapper), and ref.py (pure-jnp oracle). On CPU they run with
interpret=True; TPU is the compile target.
"""
import jax


def on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"
