"""jit'd public API for the RMSNorm kernel."""
from __future__ import annotations

from repro.kernels import on_tpu
from repro.kernels.rmsnorm.kernel import rmsnorm as _kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm(x, scale, eps: float = 1e-6, *, interpret=None):
    if interpret is None:
        interpret = not on_tpu()
    return _kernel(x, scale, eps, interpret=interpret)
