"""jit'd public API for the RMSNorm kernel."""
from __future__ import annotations

from repro.kernels import on_tpu
from repro.kernels.rmsnorm.kernel import rmsnorm as _kernel
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def rmsnorm(x, scale, eps: float = 1e-6, *, interpret=None):
    """Dispatch mirrors `repro.kernels.agg.ops`: `interpret=None` (the
    default) runs the compiled Pallas kernel on TPU and the pure-jnp
    oracle (`rmsnorm_ref`) everywhere else; explicit `interpret=True`
    forces the Pallas interpreter."""
    if interpret is None:
        if on_tpu():
            interpret = False
        else:
            return rmsnorm_ref(x, scale, eps)
    return _kernel(x, scale, eps, interpret=interpret)
