"""Pallas TPU kernel: RMSNorm over the last (model) dimension.

    y = x / sqrt(mean(x^2) + eps) * scale

Memory-bound elementwise+reduction op; tiled as (BR, D) row panels so each
grid step keeps one panel and the (D,) scale vector in VMEM. D is padded to
the 128-lane boundary by the caller (all zoo models have D % 128 == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS = 256


def _rmsnorm_kernel(eps_ref, x_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                  # (BR, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps_ref[0])
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def rmsnorm(x, scale, eps: float = 1e-6, *, rows: int = DEFAULT_ROWS,
            interpret: bool = True):
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    br = min(rows, n)
    pad = (-n) % br
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // br,)
    out = pl.pallas_call(
        _rmsnorm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),             # eps
            pl.BlockSpec((br, d), lambda i: (i, 0)),        # x panel
            pl.BlockSpec((d,), lambda i: (0,)),             # scale
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(jnp.asarray([eps], jnp.float32), xf, scale)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
