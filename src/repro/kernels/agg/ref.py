"""Pure-jnp oracle for the aggregation kernel."""
from __future__ import annotations

import jax.numpy as jnp


def weighted_aggregate_ref(params_flat, updates, weights):
    acc = jnp.tensordot(weights.astype(jnp.float32),
                        updates.astype(jnp.float32), axes=1)
    return (params_flat.astype(jnp.float32) + acc).astype(params_flat.dtype)
