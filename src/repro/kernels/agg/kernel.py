"""Pallas TPU kernel: staleness-compensated buffered aggregation (eq. 4).

    new_w[n] = w[n] + sum_m weights[m] * updates[m, n]

The server hot spot: at aggregation time the GS reduces a buffer of M
satellite updates (M up to the constellation size) over the full flat model
(N = tens-to-hundreds of millions). The reduction is memory-bound; we tile
the parameter axis into VMEM blocks and stream the (M, BN) update panel
HBM->VMEM once, accumulating in f32.

Grid: (N // BN,). BlockSpecs keep `weights` resident (it is tiny) and march
`updates`/`params` along the parameter axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 16_384


def _agg_kernel(w_ref, upd_ref, p_ref, out_ref):
    """w: (M,1) f32; upd: (M, BN); p: (BN,); out: (BN,)."""
    upd = upd_ref[...].astype(jnp.float32)          # (M, BN)
    w = w_ref[...].astype(jnp.float32)              # (M, 1)
    acc = jnp.sum(upd * w, axis=0)                  # (BN,)
    out_ref[...] = (p_ref[...].astype(jnp.float32) + acc).astype(
        out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def weighted_aggregate(params_flat, updates, weights, *,
                       block: int = DEFAULT_BLOCK, interpret: bool = True):
    """params_flat: (N,), updates: (M, N), weights: (M,) -> (N,)."""
    n = params_flat.shape[0]
    m = updates.shape[0]
    pad = (-n) % block
    if pad:
        params_flat = jnp.pad(params_flat, (0, pad))
        updates = jnp.pad(updates, ((0, 0), (0, pad)))
    np_ = params_flat.shape[0]
    grid = (np_ // block,)
    out = pl.pallas_call(
        _agg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),          # weights
            pl.BlockSpec((m, block), lambda i: (0, i)),      # updates panel
            pl.BlockSpec((block,), lambda i: (i,)),          # params block
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), params_flat.dtype),
        interpret=interpret,
    )(weights[:, None], updates, params_flat)
    return out[:n] if pad else out
