"""jit'd public API for the aggregation kernel: flat and pytree forms.

Dispatch policy (`interpret=None`, the default): on TPU the compiled Pallas
kernel runs; off TPU the pure-jnp oracle runs instead. The oracle is
bit-identical to the eager tensordot reduction the FL engine historically
used (the Pallas *interpreter* is not — its per-block elementwise reduce
accumulates in a different order), so CPU trajectories stay reproducible
while TPU gets the kernel. Pass `interpret=True` explicitly to run the
kernel through the Pallas interpreter (tests do, to validate the kernel
logic off-TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.agg.kernel import weighted_aggregate
from repro.kernels.agg.ref import weighted_aggregate_ref


def aggregate_flat(params_flat, updates, weights, *, interpret=None):
    if interpret is None:
        if on_tpu():
            interpret = False
        else:
            return weighted_aggregate_ref(params_flat, updates, weights)
    return weighted_aggregate(params_flat, updates, weights,
                              interpret=interpret)


def weighted_aggregate_tree(update_stack, weights, *, interpret=None):
    """update_stack: pytree with leading buffer dim M -> weighted sum tree
    (flattens each leaf through the kernel)."""
    def one(u):
        m = u.shape[0]
        flat = u.reshape(m, -1)
        zero = jnp.zeros((flat.shape[1],), jnp.float32)
        out = aggregate_flat(zero, flat, weights, interpret=interpret)
        return out.reshape(u.shape[1:])
    return jax.tree.map(one, update_stack)


def aggregate_params_tree(params, update_stack, weights, *, interpret=None):
    """params + sum_m w_m * updates[m] per leaf, through the kernel."""
    def one(p, u):
        m = u.shape[0]
        out = aggregate_flat(p.reshape(-1).astype(jnp.float32),
                             u.reshape(m, -1), weights, interpret=interpret)
        return out.reshape(p.shape).astype(p.dtype)
    return jax.tree.map(one, params, update_stack)
