"""Synthetic fMoW-like dataset (see DESIGN.md §7 — the real Functional Map
of the World imagery is not available offline).

Mirrors the properties the paper's evaluation depends on:
  * 62 functional categories;
  * per-sample geolocation metadata (UTM zone) — the Non-IID partitioner
    assigns samples to satellites by ground-track visits per zone;
  * a learnable signal: images are class-conditional templates + noise, so a
    small CNN/MLP actually converges and time-to-accuracy is meaningful.

Two renderings of each sample: a (H, W, 3) image for the DenseNet path and a
low-dim feature vector for fast FL sweeps.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

NUM_CLASSES = 62
NUM_UTM_ZONES = 60      # 12 longitude bands x 5 latitude bands
N_LAT_BANDS = 5
N_LON_BANDS = NUM_UTM_ZONES // N_LAT_BANDS


@dataclass(frozen=True)
class FmowSpec:
    num_train: int = 36_000        # 1/10 of the real 360k, same structure
    num_val: int = 5_304
    image_size: int = 16
    feature_dim: int = 32
    noise: float = 0.9
    class_skew_per_zone: float = 4.0   # zones see a biased class mix
    seed: int = 1234


class SyntheticFmow:
    def __init__(self, spec: FmowSpec = FmowSpec()):
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        s = spec.image_size
        # class templates in image and feature space
        self._img_t = rng.normal(0, 1, (NUM_CLASSES, s, s, 3)).astype(
            np.float32)
        self._feat_t = rng.normal(0, 1, (NUM_CLASSES, spec.feature_dim)
                                  ).astype(np.float32)
        # zone-conditional class distribution (geography skews land use).
        # Latitude is the dominant factor: each latitude band strongly
        # prefers a contiguous block of classes (tundra vs tropics vs
        # temperate land uses), plus per-zone noise.
        zone_logits = rng.normal(0, 1, (NUM_UTM_ZONES, NUM_CLASSES))
        lat_band = np.arange(NUM_UTM_ZONES) // N_LON_BANDS       # (60,)
        block = NUM_CLASSES / N_LAT_BANDS
        centers = (lat_band + 0.5) * block                       # per zone
        dist = np.abs(np.arange(NUM_CLASSES)[None, :] - centers[:, None])
        zone_logits = zone_logits - dist / block \
            * spec.class_skew_per_zone
        self._zone_p = np.exp(zone_logits)
        self._zone_p /= self._zone_p.sum(1, keepdims=True)

        def draw(n, tag):
            # crc32, not hash(): str hashing is randomized per process,
            # which made the drawn dataset itself non-reproducible
            r = np.random.default_rng(
                spec.seed + zlib.crc32(tag.encode()) % 2 ** 16)
            zones = r.integers(0, NUM_UTM_ZONES, n)
            labels = np.array([r.choice(NUM_CLASSES, p=self._zone_p[z])
                               for z in zones], np.int64)
            return zones, labels

        self.train_zones, self.train_labels = draw(spec.num_train, "train")
        self.val_zones, self.val_labels = draw(spec.num_val, "val")

    # -- renderings ------------------------------------------------------
    def _noise_rng(self, idx, split):
        return np.random.default_rng(
            (self.spec.seed * 1_000_003 + (0 if split == "train" else 1)
             * 500_009 + int(idx)) % 2 ** 63)

    def images(self, idx: np.ndarray, split: str = "train") -> np.ndarray:
        labels = (self.train_labels if split == "train"
                  else self.val_labels)[idx]
        out = self._img_t[labels].copy()
        for j, i in enumerate(idx):
            out[j] += self._noise_rng(i, split).normal(
                0, self.spec.noise, out[j].shape).astype(np.float32)
        return out

    def features(self, idx: np.ndarray, split: str = "train") -> np.ndarray:
        labels = (self.train_labels if split == "train"
                  else self.val_labels)[idx]
        out = self._feat_t[labels].copy()
        noise = np.random.default_rng(
            self.spec.seed + (0 if split == "train" else 1)
        ).normal(0, self.spec.noise, out.shape).astype(np.float32)
        # deterministic per-index noise via hashing rows of a fixed stream
        return out + noise

    def labels(self, idx: np.ndarray, split: str = "train") -> np.ndarray:
        return (self.train_labels if split == "train"
                else self.val_labels)[idx]
