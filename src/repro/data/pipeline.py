"""Minimal deterministic batching pipeline for client-local training."""
from __future__ import annotations

import numpy as np


class ClientDataset:
    """A satellite's local shard: deterministic minibatch stream."""

    def __init__(self, indices: np.ndarray, client_id: int, seed: int = 0):
        self.indices = np.asarray(indices)
        self.client_id = int(client_id)
        self.seed = seed

    def __len__(self):
        return len(self.indices)

    def batches(self, round_rng: int, batch_size: int, num_batches: int):
        """num_batches index batches for one local round (eq. 3 minibatches).
        Deterministic given (client, round_rng)."""
        if len(self.indices) == 0:
            return np.zeros((num_batches, 0), np.int64)
        rng = np.random.default_rng(
            (self.seed * 7_919 + self.client_id * 104_729 + round_rng)
            % 2 ** 63)
        picks = rng.integers(0, len(self.indices),
                             (num_batches, min(batch_size,
                                               len(self.indices))))
        return self.indices[picks]


def make_clients(parts, seed: int = 0):
    return [ClientDataset(p, k, seed) for k, p in enumerate(parts)]
