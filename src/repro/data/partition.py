"""Dataset partitioning across satellites (paper §4.1).

IID: shuffle and split uniformly across the K satellites.
Non-IID: partition samples by UTM zone; for each zone, find the satellites
whose ground track passes over it during the simulated days and assign the
zone's samples across those satellites proportionally to their number of
visits — yielding skewed labels and heterogeneous sample counts, as in the
paper.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import connectivity as CN
from repro.data.fmow import NUM_UTM_ZONES


def iid_partition(num_samples: int, K: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_samples)
    return [np.sort(p) for p in np.array_split(perm, K)]


LAT_EDGES = np.array([-90.0, -45.0, -15.0, 15.0, 45.0, 90.0])
N_LON = NUM_UTM_ZONES // (len(LAT_EDGES) - 1)   # 12 lon bands x 5 lat bands


def ground_track_zone_visits(spec: CN.ConstellationSpec, *,
                             days: float = 5.0, step_s: float = 120.0
                             ) -> np.ndarray:
    """(K, NUM_UTM_ZONES) visit counts: how often each satellite's subpoint
    falls in each (longitude-band x latitude-band) cell. Latitude matters:
    ISS-inclination satellites never overfly polar cells, sun-synchronous
    ones concentrate there — the source of the paper's per-satellite data
    heterogeneity."""
    times = np.arange(int(days * 86400 / step_s)) * step_s
    pos = CN.satellite_positions_eci(spec, times)          # (T,K,3)
    r = np.linalg.norm(pos, axis=-1)
    lat = np.degrees(np.arcsin(pos[..., 2] / r))           # (T,K)
    lon_eci = np.arctan2(pos[..., 1], pos[..., 0])
    lon = (lon_eci - (CN.OMEGA_EARTH * times)[:, None] + np.pi) \
        % (2 * np.pi) - np.pi
    lon_band = ((np.degrees(lon) + 180.0) // (360.0 / N_LON)
                ).astype(int) % N_LON
    lat_band = np.clip(np.searchsorted(LAT_EDGES, lat) - 1, 0,
                       len(LAT_EDGES) - 2)
    zone = lat_band * N_LON + lon_band
    K = pos.shape[1]
    visits = np.zeros((K, NUM_UTM_ZONES), np.int64)
    for k in range(K):
        visits[k] = np.bincount(zone[:, k], minlength=NUM_UTM_ZONES)
    return visits


def noniid_partition(sample_zones: np.ndarray, K: int,
                     spec: CN.ConstellationSpec, *, days: float = 5.0,
                     sharpen: float = 3.0, top_frac: float = 0.25,
                     seed: int = 0) -> List[np.ndarray]:
    """Assign each UTM zone's samples across the satellites that visit it,
    proportional to visit counts (paper §4.1).

    Deviation note (DESIGN.md §7): a satellite only downlinks imagery it
    captured while *directly overflying* a cell, so ownership concentrates
    among the most frequent visitors. We model that by keeping the top
    `top_frac` visitors per zone and sharpening weights with visits^sharpen
    — without this the 120 s-step ground tracks visit every cell and the
    partition degenerates to IID."""
    rng = np.random.default_rng(seed)
    visits = ground_track_zone_visits(spec, days=days)     # (K, Z)
    parts: List[list] = [[] for _ in range(K)]
    m = max(1, int(K * top_frac))
    for z in range(NUM_UTM_ZONES):
        idx = np.flatnonzero(sample_zones == z)
        if len(idx) == 0:
            continue
        rng.shuffle(idx)
        w = visits[:, z].astype(np.float64)
        if w.sum() == 0:
            w = np.ones(K)
        top = np.argsort(w)[-m:]
        wt = w[top] ** sharpen
        p = wt / wt.sum()
        owners = top[rng.choice(m, len(idx), p=p)]
        for i, o in zip(idx, owners):
            parts[o].append(i)
    return [np.sort(np.asarray(p, np.int64)) for p in parts]


def partition_stats(parts: List[np.ndarray], labels: np.ndarray) -> dict:
    sizes = np.array([len(p) for p in parts])
    # label-distribution skew: mean TV distance from global distribution
    gl = np.bincount(labels, minlength=labels.max() + 1).astype(float)
    gl /= gl.sum()
    tvs = []
    for p in parts:
        if len(p) == 0:
            continue
        d = np.bincount(labels[p], minlength=len(gl)).astype(float)
        d /= d.sum()
        tvs.append(0.5 * np.abs(d - gl).sum())
    return {"size_min": int(sizes.min()), "size_max": int(sizes.max()),
            "size_mean": float(sizes.mean()),
            "tv_mean": float(np.mean(tvs))}
