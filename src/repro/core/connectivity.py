"""Deterministic satellite-to-ground-station connectivity (paper §2.2).

Replaces the `cote` simulator (unavailable offline) with a first-principles
propagator: circular Keplerian orbits for a Planet-Flock-like constellation
(sun-synchronous, ~475 km, 97.4 deg inclination) + Earth rotation for the
ground stations + minimum-elevation-angle visibility. The output is the
sequence of connectivity sets C = {C_0, C_1, ...} with period T0 (eq. 2):
satellite k is in C_i if a link to ANY ground station is feasible at some
time inside window i.

Everything is deterministic given the constellation spec — the property
FedSpace exploits (§3.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np

MU = 3.986004418e14           # m^3/s^2
R_EARTH = 6_371_000.0         # m
OMEGA_EARTH = 7.2921159e-5    # rad/s

# 12 Planet-like ground-station sites (lat, lon) — polar-heavy, as real
# downlink networks are.
DEFAULT_GROUND_STATIONS: List[Tuple[str, float, float]] = [
    ("svalbard", 78.23, 15.39),
    ("troll_antarctica", -72.01, 2.53),
    ("inuvik", 68.32, -133.55),
    ("fairbanks", 64.86, -147.85),
    ("kiruna", 67.89, 20.41),
    ("punta_arenas", -53.16, -70.91),
    ("awarua_nz", -46.53, 168.38),
    ("hartebeesthoek", -25.89, 27.69),
    ("dubai", 25.20, 55.27),
    ("bremen", 53.08, 8.80),
    ("ohio", 40.37, -83.06),
    ("seoul", 37.57, 126.98),
]


@dataclass(frozen=True)
class ConstellationSpec:
    num_satellites: int = 191
    num_planes: int = 8
    altitude_m: float = 475_000.0
    inclination_deg: float = 97.4
    iss_fraction: float = 0.5          # Flock 2e/2e' satellites on ISS orbit
    iss_inclination_deg: float = 51.6
    iss_altitude_m: float = 420_000.0
    min_elevation_deg: float = 50.0
    raan_spread_deg: float = 360.0
    phase_jitter: float = 0.35     # fraction of slot spacing (deterministic)
    seed: int = 17
    ground_stations: Tuple[Tuple[str, float, float], ...] = tuple(
        DEFAULT_GROUND_STATIONS)


def _rot_z(a):
    c, s = np.cos(a), np.sin(a)
    z = np.zeros_like(a)
    o = np.ones_like(a)
    return np.stack([np.stack([c, -s, z], -1),
                     np.stack([s, c, z], -1),
                     np.stack([z, z, o], -1)], -2)


def satellite_elements(spec: ConstellationSpec):
    """Per-satellite (raan, inclination, phase) — deterministic."""
    rng = np.random.default_rng(spec.seed)
    K = spec.num_satellites
    planes = np.arange(K) % spec.num_planes
    raan = planes / spec.num_planes * np.deg2rad(spec.raan_spread_deg)
    per_plane = np.ceil(K / spec.num_planes)
    slot = np.arange(K) // spec.num_planes
    phase = (slot / per_plane * 2 * np.pi
             + planes * 0.5                      # inter-plane phasing
             + rng.uniform(-1, 1, K) * spec.phase_jitter
             * 2 * np.pi / per_plane)
    inc = np.full(K, np.deg2rad(spec.inclination_deg))
    n_iss = int(K * spec.iss_fraction)
    iss_idx = rng.permutation(K)[:n_iss]
    inc[iss_idx] = np.deg2rad(spec.iss_inclination_deg)
    alt = np.full(K, spec.altitude_m)
    alt[iss_idx] = spec.iss_altitude_m
    return raan, inc, phase, alt


def satellite_positions_eci(spec: ConstellationSpec, times: np.ndarray):
    """ECI positions (T, K, 3) at times (s)."""
    raan, inc, phase, alt = satellite_elements(spec)
    r = R_EARTH + alt                             # (K,)
    n = np.sqrt(MU / r ** 3)                      # mean motion rad/s (K,)
    theta = times[:, None] * n + phase[None, :]   # (T, K)
    x = r * np.cos(theta)
    y = r * np.sin(theta)
    ci, si = np.cos(inc), np.sin(inc)
    cr, sr = np.cos(raan), np.sin(raan)
    # orbit plane: rotate (x, y, 0) by inclination about x, then RAAN about z
    xi = x
    yi = y * ci
    zi = y * si
    xe = cr * xi - sr * yi
    ye = sr * xi + cr * yi
    return np.stack([xe, ye, np.broadcast_to(zi, xe.shape)], -1)


def ground_positions_eci(spec: ConstellationSpec, times: np.ndarray):
    """ECI positions (T, G, 3) of ground stations under Earth rotation."""
    lats = np.deg2rad([g[1] for g in spec.ground_stations])
    lons = np.deg2rad([g[2] for g in spec.ground_stations])
    clat = np.cos(lats)
    ecef = R_EARTH * np.stack(
        [clat * np.cos(lons), clat * np.sin(lons), np.sin(lats)], -1)  # (G,3)
    ang = OMEGA_EARTH * times                                          # (T,)
    rot = _rot_z(ang)                                                  # (T,3,3)
    return np.einsum("tij,gj->tgi", rot, ecef)


def visibility(spec: ConstellationSpec, times: np.ndarray) -> np.ndarray:
    """(T, K) bool: satellite visible from any GS above min elevation."""
    sat = satellite_positions_eci(spec, times)     # (T,K,3)
    gs = ground_positions_eci(spec, times)         # (T,G,3)
    d = sat[:, :, None, :] - gs[:, None, :, :]     # (T,K,G,3)
    up = gs / np.linalg.norm(gs, axis=-1, keepdims=True)
    dn = np.linalg.norm(d, axis=-1)
    sin_elev = np.einsum("tkgi,tgi->tkg", d, up) / np.maximum(dn, 1.0)
    vis = sin_elev >= np.sin(np.deg2rad(spec.min_elevation_deg))
    return vis.any(axis=2)


def connectivity_sets(spec: ConstellationSpec, *, t0_s: float = 900.0,
                      days: float = 5.0, substep_s: float = 60.0
                      ) -> np.ndarray:
    """C as a boolean matrix (num_windows, K): k in C_i iff a link is
    feasible at any substep inside window i (paper uses T0 = 15 min)."""
    num_windows = int(round(days * 86400.0 / t0_s))
    per = int(round(t0_s / substep_s))
    times = np.arange(num_windows * per) * substep_s
    vis = visibility(spec, times)                  # (num_windows*per, K)
    return vis.reshape(num_windows, per, -1).any(axis=1)


def connectivity_stats(C: np.ndarray, windows_per_day: int = 96) -> dict:
    """Fig. 2 statistics: |C_i| over time and per-satellite contacts/day."""
    sizes = C.sum(axis=1)
    days = C.shape[0] // windows_per_day
    nk = C[:days * windows_per_day].reshape(days, windows_per_day, -1)
    contacts_per_day = nk.sum(axis=1).mean(axis=0)   # (K,)
    return {
        "ci_min": int(sizes.min()), "ci_max": int(sizes.max()),
        "ci_mean": float(sizes.mean()),
        "nk_min": float(contacts_per_day.min()),
        "nk_max": float(contacts_per_day.max()),
        "nk_mean": float(contacts_per_day.mean()),
        "sizes": sizes, "contacts_per_day": contacts_per_day,
    }
