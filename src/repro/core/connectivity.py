"""Deterministic satellite-to-ground-station connectivity (paper §2.2).

Replaces the `cote` simulator (unavailable offline) with a first-principles
propagator: circular Keplerian orbits for a Planet-Flock-like constellation
(sun-synchronous, ~475 km, 97.4 deg inclination) + Earth rotation for the
ground stations + minimum-elevation-angle visibility. The output is the
sequence of connectivity sets C = {C_0, C_1, ...} with period T0 (eq. 2):
satellite k is in C_i if a link to ANY ground station is feasible at some
time inside window i.

Everything is deterministic given the constellation spec — the property
FedSpace exploits (§3.1).

Beyond the paper's single Planet-Flock scenario, this module carries the
constellation scenario suite: multi-shell Walker-style specs (`Shell`),
named ground-station networks (`GROUND_NETWORKS`), and registry-exposed
presets (`repro.fl.registry.CONSTELLATIONS`) from the 191-satellite
Planet-Flock baseline up to a 1000-satellite Starlink-like family — the
regimes mega-constellation FL work (Matthiesen et al. 2022, Razmi et al.
2021) evaluates. Select a preset by name through
`repro.fl.api.ConstellationConfig(preset=...)` or build one directly with
`constellation_preset`.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple

import numpy as np

from repro.fl.registry import CONSTELLATIONS, register_constellation

MU = 3.986004418e14           # m^3/s^2
R_EARTH = 6_371_000.0         # m
OMEGA_EARTH = 7.2921159e-5    # rad/s

# 12 Planet-like ground-station sites (lat, lon) — polar-heavy, as real
# downlink networks are.
DEFAULT_GROUND_STATIONS: List[Tuple[str, float, float]] = [
    ("svalbard", 78.23, 15.39),
    ("troll_antarctica", -72.01, 2.53),
    ("inuvik", 68.32, -133.55),
    ("fairbanks", 64.86, -147.85),
    ("kiruna", 67.89, 20.41),
    ("punta_arenas", -53.16, -70.91),
    ("awarua_nz", -46.53, 168.38),
    ("hartebeesthoek", -25.89, 27.69),
    ("dubai", 25.20, 55.27),
    ("bremen", 53.08, 8.80),
    ("ohio", 40.37, -83.06),
    ("seoul", 37.57, 126.98),
]


# Named ground networks for the scenario suite: the paper-like polar-heavy
# 12-site network, a mid-size commercial subset, and the degenerate
# single-station case (every model update funnels through Svalbard).
GROUND_NETWORKS: dict = {
    "dense12": tuple(DEFAULT_GROUND_STATIONS),
    "mid4": tuple(g for g in DEFAULT_GROUND_STATIONS
                  if g[0] in ("svalbard", "troll_antarctica", "inuvik",
                              "awarua_nz")),
    "sparse1": (("svalbard", 78.23, 15.39),),
}


@dataclass(frozen=True)
class Shell:
    """One Walker-style orbital shell of a multi-shell constellation."""
    num_satellites: int
    num_planes: int
    altitude_m: float
    inclination_deg: float
    raan_spread_deg: float = 360.0


@dataclass(frozen=True)
class ConstellationSpec:
    """Deterministic constellation + ground-network description.

    Two modes:
      * single-shell (default, ``shells=()``): the paper's Planet-Flock
        mix — `num_satellites` spread over `num_planes` sun-synchronous
        planes with an `iss_fraction` of them moved to the ISS orbit;
      * multi-shell (``shells`` non-empty): each `Shell` is an independent
        Walker-style layer (Starlink-like); `num_satellites` must equal
        the sum of shell sizes, and the ISS fields are ignored.

    Everything — including the phase jitter — is a pure function of the
    spec, so two processes given the same spec derive the same C (§3.1).
    """
    num_satellites: int = 191
    num_planes: int = 8
    altitude_m: float = 475_000.0
    inclination_deg: float = 97.4
    iss_fraction: float = 0.5          # Flock 2e/2e' satellites on ISS orbit
    iss_inclination_deg: float = 51.6
    iss_altitude_m: float = 420_000.0
    min_elevation_deg: float = 50.0
    raan_spread_deg: float = 360.0
    phase_jitter: float = 0.35     # fraction of slot spacing (deterministic)
    seed: int = 17
    ground_stations: Tuple[Tuple[str, float, float], ...] = tuple(
        DEFAULT_GROUND_STATIONS)
    shells: Tuple[Shell, ...] = ()


def _rot_z(a):
    c, s = np.cos(a), np.sin(a)
    z = np.zeros_like(a)
    o = np.ones_like(a)
    return np.stack([np.stack([c, -s, z], -1),
                     np.stack([s, c, z], -1),
                     np.stack([z, z, o], -1)], -2)


def satellite_elements(spec: ConstellationSpec):
    """Per-satellite (raan, inclination, phase, altitude) — deterministic.

    Single-shell specs reproduce the paper-era Planet-Flock layout
    bit-for-bit; multi-shell specs concatenate one Walker-style layer per
    `Shell`, each drawing its phase jitter from the same seeded stream.
    """
    rng = np.random.default_rng(spec.seed)
    if spec.shells:
        total = sum(s.num_satellites for s in spec.shells)
        if total != spec.num_satellites:
            raise ValueError(
                f"num_satellites={spec.num_satellites} but shells sum to "
                f"{total}: {spec.shells}")
        parts = [_shell_elements(s, rng, spec.phase_jitter)
                 for s in spec.shells]
        return tuple(np.concatenate([p[j] for p in parts])
                     for j in range(4))
    K = spec.num_satellites
    planes = np.arange(K) % spec.num_planes
    raan = planes / spec.num_planes * np.deg2rad(spec.raan_spread_deg)
    per_plane = np.ceil(K / spec.num_planes)
    slot = np.arange(K) // spec.num_planes
    phase = (slot / per_plane * 2 * np.pi
             + planes * 0.5                      # inter-plane phasing
             + rng.uniform(-1, 1, K) * spec.phase_jitter
             * 2 * np.pi / per_plane)
    inc = np.full(K, np.deg2rad(spec.inclination_deg))
    n_iss = int(K * spec.iss_fraction)
    iss_idx = rng.permutation(K)[:n_iss]
    inc[iss_idx] = np.deg2rad(spec.iss_inclination_deg)
    alt = np.full(K, spec.altitude_m)
    alt[iss_idx] = spec.iss_altitude_m
    return raan, inc, phase, alt


def _shell_elements(shell: Shell, rng: np.random.Generator,
                    phase_jitter: float):
    """Walker-style elements for one shell (same slot/plane layout and
    jitter convention as the single-shell path)."""
    K = shell.num_satellites
    planes = np.arange(K) % shell.num_planes
    raan = planes / shell.num_planes * np.deg2rad(shell.raan_spread_deg)
    per_plane = np.ceil(K / shell.num_planes)
    slot = np.arange(K) // shell.num_planes
    phase = (slot / per_plane * 2 * np.pi
             + planes * 0.5
             + rng.uniform(-1, 1, K) * phase_jitter * 2 * np.pi / per_plane)
    inc = np.full(K, np.deg2rad(shell.inclination_deg))
    alt = np.full(K, shell.altitude_m)
    return raan, inc, phase, alt


def satellite_positions_eci(spec: ConstellationSpec, times: np.ndarray):
    """ECI positions (T, K, 3) at times (s)."""
    raan, inc, phase, alt = satellite_elements(spec)
    r = R_EARTH + alt                             # (K,)
    n = np.sqrt(MU / r ** 3)                      # mean motion rad/s (K,)
    theta = times[:, None] * n + phase[None, :]   # (T, K)
    x = r * np.cos(theta)
    y = r * np.sin(theta)
    ci, si = np.cos(inc), np.sin(inc)
    cr, sr = np.cos(raan), np.sin(raan)
    # orbit plane: rotate (x, y, 0) by inclination about x, then RAAN about z
    xi = x
    yi = y * ci
    zi = y * si
    xe = cr * xi - sr * yi
    ye = sr * xi + cr * yi
    return np.stack([xe, ye, np.broadcast_to(zi, xe.shape)], -1)


def ground_positions_eci(spec: ConstellationSpec, times: np.ndarray):
    """ECI positions (T, G, 3) of ground stations under Earth rotation."""
    lats = np.deg2rad([g[1] for g in spec.ground_stations])
    lons = np.deg2rad([g[2] for g in spec.ground_stations])
    clat = np.cos(lats)
    ecef = R_EARTH * np.stack(
        [clat * np.cos(lons), clat * np.sin(lons), np.sin(lats)], -1)  # (G,3)
    ang = OMEGA_EARTH * times                                          # (T,)
    rot = _rot_z(ang)                                                  # (T,3,3)
    return np.einsum("tij,gj->tgi", rot, ecef)


def visibility(spec: ConstellationSpec, times: np.ndarray, *,
               time_chunk: int = 128) -> np.ndarray:
    """(T, K) bool: satellite visible from any GS above min elevation.

    Computed in time blocks of `time_chunk` steps so peak memory is
    O(time_chunk * K * G) instead of O(T * K * G) — at mega-constellation
    scale (K=1000, G=12, multi-day horizons) the one-shot broadcast is
    multiple GB while the blocked sweep stays a few tens of MB. Results
    are bit-identical to the unblocked computation (pure slicing).
    """
    time_chunk = max(int(time_chunk), 1)
    out = np.empty((len(times), spec.num_satellites), bool)
    for t0 in range(0, len(times), time_chunk):
        out[t0:t0 + time_chunk] = _visibility_block(
            spec, times[t0:t0 + time_chunk])
    return out


def _station_visibility_block(spec: ConstellationSpec, times: np.ndarray):
    """(T, K, G) bool: satellite k visible from station g at each time."""
    sat = satellite_positions_eci(spec, times)     # (T,K,3)
    gs = ground_positions_eci(spec, times)         # (T,G,3)
    d = sat[:, :, None, :] - gs[:, None, :, :]     # (T,K,G,3)
    up = gs / np.linalg.norm(gs, axis=-1, keepdims=True)
    dn = np.linalg.norm(d, axis=-1)
    sin_elev = np.einsum("tkgi,tgi->tkg", d, up) / np.maximum(dn, 1.0)
    return sin_elev >= np.sin(np.deg2rad(spec.min_elevation_deg))


def _visibility_block(spec: ConstellationSpec, times: np.ndarray):
    return _station_visibility_block(spec, times).any(axis=2)


def connectivity_sets(spec: ConstellationSpec, *, t0_s: float = 900.0,
                      days: float = 5.0, substep_s: float = 60.0
                      ) -> np.ndarray:
    """C as a boolean matrix (num_windows, K): k in C_i iff a link is
    feasible at any substep inside window i (paper uses T0 = 15 min)."""
    num_windows = int(round(days * 86400.0 / t0_s))
    per = int(round(t0_s / substep_s))
    times = np.arange(num_windows * per) * substep_s
    vis = visibility(spec, times)                  # (num_windows*per, K)
    return vis.reshape(num_windows, per, -1).any(axis=1)


def connectivity_stats(C: np.ndarray, windows_per_day: int = 96) -> dict:
    """Fig. 2 statistics: |C_i| over time and per-satellite contacts/day.

    Args:
      C: (num_windows, K) bool connectivity matrix.
      windows_per_day: calendar scaling for the contacts/day figures
        (96 = 15-minute windows).

    Returns a dict with scalar summaries (ci_min/ci_max/ci_mean over
    per-window set sizes, nk_min/nk_max/nk_mean over per-satellite
    contacts per day) plus the underlying `sizes` (num_windows,) and
    `contacts_per_day` (K,) arrays. Horizons shorter than one day are
    rate-scaled instead of producing NaN, so scenario smoke runs can
    sanity-check presets on a handful of windows.
    """
    C = np.asarray(C, bool)
    sizes = C.sum(axis=1)
    days = C.shape[0] // windows_per_day
    if days >= 1:
        nk = C[:days * windows_per_day].reshape(days, windows_per_day, -1)
        contacts_per_day = nk.sum(axis=1).mean(axis=0)   # (K,)
    else:   # sub-day horizon: scale the observed contact rate to a day
        contacts_per_day = C.sum(axis=0) * (windows_per_day / C.shape[0])
    return {
        "ci_min": int(sizes.min()), "ci_max": int(sizes.max()),
        "ci_mean": float(sizes.mean()),
        "nk_min": float(contacts_per_day.min()),
        "nk_max": float(contacts_per_day.max()),
        "nk_mean": float(contacts_per_day.mean()),
        "sizes": sizes, "contacts_per_day": contacts_per_day,
    }


# ---------------------------------------------------------------------------
# Link budgets: per-window transfer progress under finite link rates and
# per-ground-station contact capacity.
#
# The geometry layer above answers "can satellite k talk to ANY station in
# window i?" — a contact is then a free, instantaneous model transfer. The
# layer below keeps the per-station axis and turns each window into a
# *transfer budget*: how many propagation substeps of contact satellite k
# gets at the one station it is deterministically assigned to, after
# stations with more visible satellites than concurrent-contact capacity
# turn the surplus away. The FL engine and the eq.-13 schedule search
# consume the result (`LinkBudget`) through `repro.core.staleness.LinkGate`:
# an upload/download completes only after enough contact windows accumulate
# (Matthiesen et al. 2022 and Razmi et al. 2021 treat exactly these link
# rates and shared-station contention as the binding constraints).


@dataclass(frozen=True)
class LinkBudget:
    """Capacity-resolved transfer layer derived from station visibility.

    Fields (all windows x K unless noted):
      visible: raw geometric connectivity — bit-identical to
        `connectivity_sets` for the same spec/horizon.
      served: effective connectivity after contention — the satellite holds
        an assigned station contact this window. ``visible & ~served`` are
        the contacts turned away at capacity-saturated stations.
      assign: assigned station index per window (int32, -1 = unserved).
      grants: contact units (visible substeps at the assigned station) per
        window (int32, 0 when unserved).
      need_up / need_dn: units a full model upload / download takes
        (0 = instantaneous; see `transfer_windows`).

    Infinite capacity and zero latency (``gs_capacity=0`` and both needs 0)
    make `served == visible` and gate nothing — the engine and search then
    reproduce the geometry-only trajectories bit-for-bit (the parity gate
    in `benchmarks/hotpaths.py` enforces this).
    """
    visible: np.ndarray
    served: np.ndarray
    assign: np.ndarray
    grants: np.ndarray
    need_up: int
    need_dn: int

    @property
    def num_windows(self) -> int:
        return self.served.shape[0]

    def blocked_fraction(self) -> float:
        """Fraction of geometric contacts turned away by contention."""
        vis = int(self.visible.sum())
        return float((self.visible & ~self.served).sum()) / max(vis, 1)


def station_windows(spec: ConstellationSpec, *, t0_s: float = 900.0,
                    days: float = 5.0, substep_s: float = 60.0,
                    time_chunk: int = 128) -> np.ndarray:
    """(num_windows, K, G) int32: visible propagation substeps per window
    per satellite-station pair — the per-pair contact-time matrix the
    contention/transfer layer is derived from. Computed in window-aligned
    time blocks (same blocking idea as `visibility`), so peak memory stays
    O(block * K * G); `(station_windows(...) > 0).any(-1)` is bit-identical
    to `connectivity_sets` for the same arguments."""
    num_windows = int(round(days * 86400.0 / t0_s))
    per = int(round(t0_s / substep_s))
    K, G = spec.num_satellites, len(spec.ground_stations)
    wchunk = max(1, int(time_chunk) // per)         # windows per block
    counts = np.empty((num_windows, K, G), np.int32)
    for w0 in range(0, num_windows, wchunk):
        w1 = min(w0 + wchunk, num_windows)
        times = np.arange(w0 * per, w1 * per) * substep_s
        vis = _station_visibility_block(spec, times)    # (block*per, K, G)
        counts[w0:w1] = vis.reshape(w1 - w0, per, K, G).sum(
            axis=1, dtype=np.int32)
    return counts


def resolve_contention(counts: np.ndarray, capacity: int = 0) -> np.ndarray:
    """Assign each satellite to at most one station per window, stations to
    at most `capacity` satellites: (num_windows, K) int32 station index,
    -1 = unserved.

    Deterministic, state-independent rule (so the schedule search and the
    engine see the same effective connectivity without simulating each
    other): per window, stations claim satellites in station-index order;
    each station claims its unclaimed visible satellites longest-contact
    first (ties: lowest satellite index), up to `capacity`. ``capacity <=
    0`` means unlimited — every visible satellite is served by its
    longest-contact station (ties: lowest station index), so the served
    mask equals raw visibility."""
    counts = np.asarray(counts)
    nw, K, G = counts.shape
    assign = np.full((nw, K), -1, np.int32)
    if capacity <= 0:
        vis = counts.max(axis=2) > 0
        best = counts.argmax(axis=2).astype(np.int32)
        assign[vis] = best[vis]
        return assign
    for i in range(nw):
        taken = np.zeros(K, bool)
        for g in range(G):
            c = counts[i, :, g]
            cand = np.flatnonzero((c > 0) & ~taken)
            if cand.size == 0:
                continue
            # longest contact first, satellite index breaking ties
            pick = cand[np.lexsort((cand, -c[cand]))][:capacity]
            assign[i, pick] = g
            taken[pick] = True
    return assign


def transfer_windows(rate_mbps: float, size_mb: float,
                     substep_s: float = 60.0) -> int:
    """Contact units (propagation substeps) a `size_mb`-megabyte transfer
    takes at `rate_mbps` megabits/s. 0 — the instantaneous sentinel — when
    either the rate or the size is unconstrained (<= 0)."""
    if rate_mbps <= 0 or size_mb <= 0:
        return 0
    return int(np.ceil(size_mb * 8.0 / rate_mbps / substep_s))


def link_budget(spec: ConstellationSpec, *, days: float,
                uplink_mbps: float = 0.0, downlink_mbps: float = 0.0,
                model_mb: float = 0.0, gs_capacity: int = 0,
                t0_s: float = 900.0, substep_s: float = 60.0,
                counts: Optional[np.ndarray] = None,
                uplink_mb: Optional[float] = None) -> LinkBudget:
    """Derive the capacity-resolved transfer layer for a constellation:
    station-level contact times (`station_windows`), deterministic
    contention (`resolve_contention`), and the per-direction unit needs
    (`transfer_windows`). The zero sentinels (rates/model size 0 =
    instantaneous, capacity 0 = unlimited) degrade each constraint
    independently; with all of them zero the budget gates nothing.

    `uplink_mb` overrides the *uploaded* payload size (default: the full
    `model_mb`) — satellites uplink updates, which compression shrinks,
    while the downlink still carries the full model. The experiment layer
    passes `model_mb * uplink_bytes_ratio(...)` here, which is how a
    compressed update genuinely needs fewer contact units.

    `counts` accepts a precomputed `station_windows` result (callers that
    also need the per-station counts — e.g. the fault layer's station-up
    reach mask — propagate once and share the array)."""
    if counts is None:
        counts = station_windows(spec, t0_s=t0_s, days=days,
                                 substep_s=substep_s)
    assign = resolve_contention(counts, gs_capacity)
    served = assign >= 0
    grants = np.where(
        served, np.take_along_axis(counts, np.maximum(assign, 0)[..., None],
                                   axis=2)[..., 0], 0).astype(np.int32)
    up_mb = model_mb if uplink_mb is None else uplink_mb
    return LinkBudget(
        visible=counts.max(axis=2) > 0, served=served, assign=assign,
        grants=grants,
        need_up=transfer_windows(uplink_mbps, up_mb, substep_s),
        need_dn=transfer_windows(downlink_mbps, model_mb, substep_s))


# ---------------------------------------------------------------------------
# Scenario suite: registry-exposed constellation presets.
#
# Every preset is a factory `f(*, ground=None, **overrides) ->
# ConstellationSpec`: `ground` picks a GROUND_NETWORKS entry (None keeps
# the preset's default), remaining overrides are `dataclasses.replace`
# fields — so any scheduler runs on any preset, ground network, and knob
# combination through one declarative path.


def resolve_spec(base: ConstellationSpec, ground=None,
                 overrides=None) -> ConstellationSpec:
    """Apply a named ground network and field overrides to `base`.

    `ground` (a GROUND_NETWORKS key, None = keep base) is applied first,
    then `overrides` replace fields — so an explicit
    ``overrides["ground_stations"]`` wins over `ground`, identically for
    preset and ad-hoc construction paths. Unknown network names raise a
    KeyError listing what is known."""
    if ground is not None:
        try:
            stations = GROUND_NETWORKS[ground]
        except KeyError:
            known = ", ".join(sorted(GROUND_NETWORKS))
            raise KeyError(f"unknown ground network {ground!r}; known: "
                           f"{known}") from None
        base = replace(base, ground_stations=stations)
    return replace(base, **overrides) if overrides else base


@register_constellation("flock191")
def flock191(*, ground=None, **overrides):
    """The paper's scenario: 191 Planet-Flock satellites (§2.1), half on
    the ISS orbit, against the polar-heavy 12-station network."""
    return resolve_spec(ConstellationSpec(), ground, overrides)


# Starlink-like multi-shell family. Shell geometry loosely follows the
# phase-1 Starlink shells (53.0 / 53.2 deg mid-inclination + a polar
# layer); gateway terminals track to lower elevation than Planet's
# imaging downlinks, hence min_elevation 25 deg.
_STARLINK_FAMILY = {
    "starlink40": (Shell(24, 4, 550_000.0, 53.0),
                   Shell(16, 4, 560_000.0, 97.6)),
    "starlink120": (Shell(72, 6, 550_000.0, 53.0),
                    Shell(32, 4, 540_000.0, 53.2),
                    Shell(16, 4, 560_000.0, 97.6)),
    "starlink400": (Shell(240, 12, 550_000.0, 53.0),
                    Shell(96, 8, 540_000.0, 53.2),
                    Shell(64, 8, 560_000.0, 97.6)),
    "starlink1000": (Shell(600, 24, 550_000.0, 53.0),
                     Shell(240, 12, 540_000.0, 53.2),
                     Shell(160, 10, 560_000.0, 97.6)),
}


def _register_starlink(name: str, shells: Tuple[Shell, ...]):
    def factory(*, ground=None, **overrides):
        base = ConstellationSpec(
            num_satellites=sum(s.num_satellites for s in shells),
            shells=shells, min_elevation_deg=25.0)
        return resolve_spec(base, ground, overrides)
    factory.__name__ = name
    factory.__doc__ = (f"Starlink-like multi-shell constellation with "
                       f"{sum(s.num_satellites for s in shells)} "
                       f"satellites over {len(shells)} shells.")
    register_constellation(name, factory)
    return factory


for _name, _shells in _STARLINK_FAMILY.items():
    _register_starlink(_name, _shells)


def constellation_preset(name: str, *, ground: str = None,
                         **overrides) -> ConstellationSpec:
    """Build a registered constellation preset by name.

    Args:
      name: preset key (`repro.fl.registry.CONSTELLATIONS`; unknown names
        raise a KeyError listing what is registered).
      ground: optional GROUND_NETWORKS key ("dense12", "mid4", "sparse1")
        replacing the preset's default station set.
      **overrides: ConstellationSpec fields to replace (min_elevation_deg,
        seed, ...).

    Returns the fully-resolved `ConstellationSpec`.
    """
    return CONSTELLATIONS.build(name, ground=ground, **overrides)
