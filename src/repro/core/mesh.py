"""Device-mesh layer for the simulation itself: the satellite axis of the
Algorithm-1 protocol state sharded across devices.

Everything up to PR 7 runs the protocol on one device; this module is the
substrate that lets K >= 10^4 constellations fit and scale. It has three
jobs:

  * **version compatibility** — `shard_map` / `AbstractMesh` moved and
    renamed arguments across jax releases (``check_rep`` became
    ``check_vma``; ``AbstractMesh`` switched from positional
    ``(shape, axis_names)`` to ``(name, size)`` pairs and back). `shard_map`
    and `abstract_mesh` here resolve the installed spelling once, so the
    model-parallel stack (`repro.models.moe`, `repro.launch.steps`), the
    protocol scans, and the sharding tests all run against pinned *and*
    latest jax — these shims are what un-xfailed the seed-era sharding
    tests.
  * **the simulation mesh** — `sim_mesh` builds the 1-D ``"sat"`` mesh the
    engine (`repro.fl.engine.SimulationEngine(mesh=...)`) and the eq.-13
    search (`repro.core.search.score_candidates(mesh=...)`) shard the
    satellite axis over. The protocol transitions are embarrassingly
    parallel over K between aggregation events: the only cross-satellite
    contractions are the scalar counters/any-buffer reductions (exact
    integer `psum`s — see the ``axis_name`` threading in
    `repro.core.staleness`) and the (K,)-sized ISL neighbour/sink gathers
    (`all_gather` of one bool/int row per window).
  * **padding** — device counts rarely divide K, so `padded_size` /
    `pad_axis` / `pad_state` extend the satellite axis with never-connected
    satellites (connectivity False, grants 0, state "never existed"). A
    satellite with no contact ever uploads, downloads, gossips, idles, or
    enters the buffer, so every counter and every real satellite's
    trajectory is bit-identical to the unpadded run — that is the parity
    contract `docs/scaling.md` spells out and the mesh tests/benchmark
    gate enforce.
"""
from __future__ import annotations

import functools
import inspect
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS

SAT_AXIS = "sat"


# ---------------------------------------------------------------------------
# version compatibility


@functools.lru_cache(maxsize=None)
def _resolve_shard_map():
    """(shard_map callable, name of its replication-check kwarg)."""
    try:
        from jax import shard_map as fn          # jax >= 0.6 spelling
    except ImportError:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    kw = "check_vma" if "check_vma" in params else (
        "check_rep" if "check_rep" in params else None)
    return fn, kw


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """`jax.shard_map` under whichever name/signature the installed jax
    ships. `check` maps onto ``check_vma`` (current) or ``check_rep``
    (jax <= 0.4.x); it defaults to False because the protocol scans emit
    psum-replicated outputs from inside `lax.scan`, which the static
    replication checkers mis-track on some pinned versions — parity with
    the single-device program is asserted by tests instead."""
    fn, kw = _resolve_shard_map()
    kwargs = {} if kw is None else {kw: check}
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **kwargs)


def abstract_mesh(shape, axis_names):
    """`jax.sharding.AbstractMesh` across signature generations: modern
    jax takes positional ``(axis_sizes, axis_names)``, the 0.4.x line a
    single tuple of ``(name, size)`` pairs. Spec-only computations (no
    devices needed) build their mesh here."""
    AM = jax.sharding.AbstractMesh
    try:
        return AM(tuple(shape), tuple(axis_names))
    except TypeError:
        return AM(tuple(zip(axis_names, shape)))


# ---------------------------------------------------------------------------
# the simulation mesh


def sim_mesh(num_devices: Optional[int] = None, *,
             axis: str = SAT_AXIS) -> jax.sharding.Mesh:
    """1-D device mesh over the satellite axis. All visible devices by
    default (`num_devices` clips — e.g. to benchmark scaling curves);
    a single-device mesh is valid and compiles the shard_map path with
    trivial collectives, which is how the mesh code stays exercised on
    1-device CI runners."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else max(1, min(int(num_devices),
                                                         len(devs)))
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))


def mesh_size(mesh) -> int:
    """Total device count of a mesh (the satellite-axis shard count)."""
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names]))


def sat_sharding(mesh) -> jax.sharding.NamedSharding:
    """NamedSharding placing a (..., K)-last-axis-leading (K,) array along
    the mesh's satellite axis."""
    return jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(mesh.axis_names[0]))


# ---------------------------------------------------------------------------
# satellite-axis padding (never-connected satellites: trajectory-inert)


def padded_size(K: int, mesh) -> int:
    """Smallest multiple of the mesh's device count >= K."""
    n = mesh_size(mesh)
    return -(-int(K) // n) * n


def pad_axis(arr, total: int, *, axis: int = -1, fill=0):
    """Pad `arr` with `fill` along `axis` up to length `total` (host
    numpy). The fill values model satellites that do not exist: False
    connectivity/alive rows, zero grants, self-loop neighbour indices."""
    arr = np.asarray(arr)
    pad = total - arr.shape[axis]
    if pad <= 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis if axis >= 0 else arr.ndim + axis] = (0, pad)
    return np.pad(arr, widths, constant_values=fill)


def pad_state(state: SS.SatState, total: int) -> SS.SatState:
    """Extend a (K,) `SatState` to `total` satellites that were never
    seeded (version/pending/buffered -1, zero progress/relay). Combined
    with all-False connectivity columns the padding is trajectory-inert:
    no upload (nothing pending), no download (never connected), no idle
    or buffer contribution, no fault revive, and self-loop ISL entries
    neither offer nor adopt anything."""
    K = state.version.shape[-1]
    pad = total - K
    if pad <= 0:
        return state

    def ext(x, fill):
        return jnp.concatenate(
            [x, jnp.full(x.shape[:-1] + (pad,), fill, x.dtype)], axis=-1)

    return SS.SatState(
        version=ext(state.version, -1),
        pending=ext(state.pending, -1),
        buffered=ext(state.buffered, -1),
        progress=None if state.progress is None else ext(state.progress, 0),
        relay=None if state.relay is None else ext(state.relay, 0))
