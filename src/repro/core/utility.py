"""Utility-function estimation (paper §3.2, eq. 12).

The GS (i) trains a model on a source dataset and stores the checkpoint
trajectory {w^0..w^Imax}; (ii) samples (staleness vector s, training status
T) pairs; (iii) measures the loss drop Δf of applying the staleness-vector's
local updates to w^{i_start}; (iv) fits a regression model û(φ(s), T) ≈ Δf.

Featurization φ: staleness vectors live in {-1,0,..,s_max}^K with K varying
across constellations, so we use the *histogram* of staleness values (counts
of gradients at each staleness 0..s_max) + total count + T. This is the same
feature the schedule simulator (repro.core.staleness) emits, so the search
can score candidates without materializing per-satellite vectors.

Two regressors: a from-scratch random forest (paper-faithful: "a standard
random forest regression") and a JAX MLP (beyond-paper alternative).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def featurize(hist: np.ndarray, status: float) -> np.ndarray:
    """hist: (..., s_max+1) counts; status: scalar training status T.

    Features: raw histogram + derived physical quantities the utility
    actually depends on — total count (direction variance ~ 1/count under
    eq. 4 normalization), staleness-compensated mass sum_s hist_s * c(s),
    and mean staleness — plus T."""
    hist = np.asarray(hist, np.float32)
    total = hist.sum(axis=-1, keepdims=True)
    s_vals = np.arange(hist.shape[-1], dtype=np.float32)
    c = (s_vals + 1.0) ** -0.5
    fresh_mass = (hist * c).sum(axis=-1, keepdims=True)
    mean_stale = (hist * s_vals).sum(axis=-1, keepdims=True) \
        / np.maximum(total, 1.0)
    stat = np.broadcast_to(np.float32(status), total.shape)
    return np.concatenate([hist, total, fresh_mass, mean_stale, stat],
                          axis=-1)


@functools.partial(jax.jit, static_argnames=("s_max",))
def _featurize_jnp(hist, status, *, s_max: int):
    hist = hist.astype(jnp.float32)
    total = hist.sum(axis=-1, keepdims=True)
    s_vals = jnp.arange(s_max + 1, dtype=jnp.float32)
    # c(s) table precomputed on host so both featurize paths share the
    # exact same float32 constants
    c = jnp.asarray((np.arange(s_max + 1, dtype=np.float32) + 1.0) ** -0.5)
    fresh_mass = (hist * c).sum(axis=-1, keepdims=True)
    mean_stale = (hist * s_vals).sum(axis=-1, keepdims=True) \
        / jnp.maximum(total, 1.0)
    stat = jnp.broadcast_to(jnp.float32(status), total.shape)
    return jnp.concatenate([hist, total, fresh_mass, mean_stale, stat],
                           axis=-1)


def featurize_jnp(hist, status):
    """Device-resident `featurize`: same features, jnp end-to-end (accepts
    and returns jnp arrays; XLA reduction order may differ from the host
    path by ~1 ulp)."""
    return _featurize_jnp(hist, jnp.float32(status),
                          s_max=hist.shape[-1] - 1)


def n_features(s_max: int) -> int:
    """Width of `featurize`'s output: the raw histogram (s_max+1) plus
    total count, staleness-compensated fresh mass, mean staleness, and the
    training status T. Depends only on `s_max`, never on K — which is what
    makes a fitted regressor transferable across constellations."""
    return s_max + 5


def transfer_ready(regressor, *, s_max: int = 8) -> bool:
    """Forest-transfer predicate: True when `regressor` can serve eq.-13
    schedule searches on *any* constellation at this `s_max` without
    refitting. The featurization is K-agnostic by construction (histogram
    counts scale with K, the feature semantics don't — paper §3.2), so the
    hard requirements are a matching feature width (when the regressor
    records one at fit time) and a device prediction path (the search and
    the replan service stay on device end-to-end)."""
    nf = getattr(regressor, "n_features_", None)
    if nf is not None and int(nf) != n_features(s_max):
        return False
    return callable(getattr(regressor, "predict_device", None))


def transfer_report(regressor, feats) -> dict:
    """Cross-constellation evaluation: how a feature batch from a *other*
    constellation than the fit (e.g. flock191-fitted û asked about
    starlink400 histograms) sits relative to the regressor's training
    envelope, plus a prediction summary.

    Tree ensembles extrapolate as constants beyond their training
    envelope — out-of-envelope counts from a larger K saturate the
    fresh-mass/total splits rather than exploding — so `in_envelope` below
    1.0 flags *reduced resolution*, not invalid predictions. Returns:
      rows, finite (inputs all finite), in_envelope (fraction of feature
      values inside the per-feature fit range; only when the regressor
      recorded one), out_features (feature indices with any value outside
      the envelope), pred_min/pred_max/pred_finite.
    """
    X = np.asarray(feats, np.float32)
    if X.ndim == 1:
        X = X[None, :]
    out = {"rows": int(X.shape[0]),
           "finite": bool(np.isfinite(X).all())}
    lo = getattr(regressor, "feature_low_", None)
    hi = getattr(regressor, "feature_high_", None)
    if lo is not None and hi is not None:
        inside = (X >= lo) & (X <= hi)
        out["in_envelope"] = float(inside.mean())
        out["out_features"] = [int(j) for j in
                               np.flatnonzero(~inside.all(axis=0))]
    preds = np.asarray(regressor.predict(X))
    out["pred_min"] = float(preds.min())
    out["pred_max"] = float(preds.max())
    out["pred_finite"] = bool(np.isfinite(preds).all())
    return out


def _record_envelope(regressor, X):
    """Remember the fit's feature geometry (width + per-feature range) so
    `transfer_ready` / `transfer_report` can reason about serving other
    constellations. Pure metadata — predictions are untouched."""
    regressor.n_features_ = int(X.shape[1])
    regressor.feature_low_ = X.min(axis=0)
    regressor.feature_high_ = X.max(axis=0)


# ---------------------------------------------------------------------------
# Random forest (numpy CART ensemble)


@dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: int = -1
    right: int = -1
    value: float = 0.0


@dataclass(frozen=True)
class ForestArrays:
    """Structure-of-arrays view of a fitted forest: (n_trees, max_nodes)
    per-node fields, leaf-padded so every tree shares one node axis.
    `feature < 0` marks a leaf; leaf left/right self-loop to node 0 so the
    level-wise traversal below is branch-free."""
    feature: np.ndarray    # (T, M) int32, -1 at leaves / padding
    thresh: np.ndarray     # (T, M) f32
    left: np.ndarray       # (T, M) int32
    right: np.ndarray      # (T, M) int32
    value: np.ndarray      # (T, M) f32
    depth: int             # max root-to-leaf edge count


def forest_to_arrays(trees: List[List[_Node]], max_depth: int
                     ) -> ForestArrays:
    T = len(trees)
    M = max(len(t) for t in trees)
    feature = np.full((T, M), -1, np.int32)
    thresh = np.zeros((T, M), np.float32)
    left = np.zeros((T, M), np.int32)
    right = np.zeros((T, M), np.int32)
    value = np.zeros((T, M), np.float32)
    for ti, nodes in enumerate(trees):
        for ni, n in enumerate(nodes):
            feature[ti, ni] = n.feature
            thresh[ti, ni] = n.thresh
            left[ti, ni] = max(n.left, 0)
            right[ti, ni] = max(n.right, 0)
            value[ti, ni] = n.value
    return ForestArrays(feature, thresh, left, right, value, max_depth)


def forest_predict_np(fa: ForestArrays, X: np.ndarray) -> np.ndarray:
    """Vectorized level-wise traversal: every (tree, row) pair walks one
    level per iteration; rows already at a leaf stay put. Bit-matches the
    per-row node walk (same leaf values, same f32 mean over trees)."""
    X = np.asarray(X, np.float32)
    T, N = fa.feature.shape[0], X.shape[0]
    rows = np.arange(T)[:, None]
    cols = np.arange(N)[None, :]
    idx = np.zeros((T, N), np.int32)
    for _ in range(fa.depth):
        f = fa.feature[rows, idx]
        leaf = f < 0
        xv = X[cols, np.clip(f, 0, X.shape[1] - 1)]
        go_left = xv <= fa.thresh[rows, idx]
        nxt = np.where(go_left, fa.left[rows, idx], fa.right[rows, idx])
        idx = np.where(leaf, idx, nxt)
    return fa.value[rows, idx].mean(axis=0)


@functools.partial(jax.jit, static_argnames=("depth",))
def _forest_predict_device(feature, thresh, left, right, value, offsets,
                           X, *, depth: int):
    """Level-wise traversal over the flattened forest. All node fields are
    1-D (total_nodes,) arrays and `offsets` (T, 1) holds each tree's root
    index: 1-D `jnp.take` gathers lower much faster on CPU than the 2-D
    take_along_axis equivalent. left/right store tree-local child indices,
    hence the `offsets +` rebase each level."""
    T = offsets.shape[0]
    N, F = X.shape
    Xf = X.reshape(-1)
    cols = jnp.arange(N)[None, :]

    def body(_, idx):
        f = jnp.take(feature, idx)
        leaf = f < 0
        xv = jnp.take(Xf, cols * F + jnp.clip(f, 0, F - 1))
        go_left = xv <= jnp.take(thresh, idx)
        nxt = offsets + jnp.where(go_left, jnp.take(left, idx),
                                  jnp.take(right, idx))
        return jnp.where(leaf, idx, nxt)

    idx = jax.lax.fori_loop(0, depth, body,
                            jnp.broadcast_to(offsets, (T, N)))
    return jnp.take(value, idx).mean(axis=0)


class RandomForestRegressor:
    def __init__(self, n_trees: int = 40, max_depth: int = 6,
                 min_leaf: int = 4, feature_frac: float = 0.8,
                 seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_leaf = min_leaf
        self.feature_frac = feature_frac
        self.seed = seed
        self.trees: List[List[_Node]] = []
        self._arrays: Optional[ForestArrays] = None
        self._device_arrays = None

    def _build(self, X, y, rng) -> List[_Node]:
        nodes: List[_Node] = []

        def grow(idx, depth) -> int:
            node = _Node(value=float(y[idx].mean()))
            nodes.append(node)
            me = len(nodes) - 1
            if depth >= self.max_depth or len(idx) < 2 * self.min_leaf \
                    or np.ptp(y[idx]) < 1e-12:
                return me
            nf = max(1, int(X.shape[1] * self.feature_frac))
            feats = rng.choice(X.shape[1], nf, replace=False)
            best = (None, None, np.inf)
            for f in feats:
                xs = X[idx, f]
                order = np.argsort(xs)
                xs_s, ys_s = xs[order], y[idx][order]
                csum = np.cumsum(ys_s)
                csq = np.cumsum(ys_s ** 2)
                n = len(ys_s)
                for cut in range(self.min_leaf, n - self.min_leaf):
                    if xs_s[cut] == xs_s[cut - 1]:
                        continue
                    ln, rn = cut, n - cut
                    lsum, lsq = csum[cut - 1], csq[cut - 1]
                    rsum, rsq = csum[-1] - lsum, csq[-1] - lsq
                    sse = (lsq - lsum ** 2 / ln) + (rsq - rsum ** 2 / rn)
                    if sse < best[2]:
                        best = (f, (xs_s[cut] + xs_s[cut - 1]) / 2, sse)
            if best[0] is None:
                return me
            f, t, _ = best
            mask = X[idx, f] <= t
            node.feature, node.thresh = int(f), float(t)
            node.left = grow(idx[mask], depth + 1)
            node.right = grow(idx[~mask], depth + 1)
            return me

        grow(np.arange(len(y)), 0)
        return nodes

    def fit(self, X, y):
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        rng = np.random.default_rng(self.seed)
        self.trees = []
        for _ in range(self.n_trees):
            boot = rng.integers(0, len(y), len(y))
            self.trees.append(self._build(X[boot], y[boot], rng))
        self._arrays = None
        self._device_arrays = None
        _record_envelope(self, X)
        return self

    def arrays(self) -> ForestArrays:
        """Structure-of-arrays view, built once per fit."""
        if self._arrays is None:
            self._arrays = forest_to_arrays(self.trees, self.max_depth)
        return self._arrays

    def _predict_tree(self, nodes: List[_Node], X) -> np.ndarray:
        out = np.empty(len(X), np.float32)
        for i, x in enumerate(X):
            n = 0
            while nodes[n].feature >= 0:
                n = nodes[n].left if x[nodes[n].feature] <= nodes[n].thresh \
                    else nodes[n].right
            out[i] = nodes[n].value
        return out

    def predict_reference(self, X) -> np.ndarray:
        """Per-row, per-tree node walk — the O(rows * trees) pure-Python
        oracle the vectorized paths are tested against."""
        X = np.asarray(X, np.float32)
        return np.mean([self._predict_tree(t, X) for t in self.trees],
                       axis=0)

    def predict(self, X) -> np.ndarray:
        X = np.asarray(X, np.float32)
        return forest_predict_np(self.arrays(), X)

    def predict_device(self, X):
        """jit-compatible prediction on a jnp feature batch; stays on
        device (the schedule search feeds simulator histograms straight in
        with no host round-trip)."""
        fa = self.arrays()
        if self._device_arrays is None:
            T, M = fa.feature.shape
            offsets = (np.arange(T, dtype=np.int32) * M)[:, None]
            self._device_arrays = tuple(
                jnp.asarray(a.reshape(-1))
                for a in (fa.feature, fa.thresh, fa.left, fa.right,
                          fa.value)) + (jnp.asarray(offsets),)
        return _forest_predict_device(*self._device_arrays,
                                      jnp.asarray(X), depth=fa.depth)


# ---------------------------------------------------------------------------
# JAX MLP regressor (beyond-paper alternative)


class MLPRegressor:
    def __init__(self, hidden: int = 64, steps: int = 800, lr: float = 1e-2,
                 seed: int = 0):
        self.hidden = hidden
        self.steps = steps
        self.lr = lr
        self.seed = seed
        self.params = None
        self.mu = self.sd = self.ymu = self.ysd = None

    def _apply(self, p, x):
        h = jnp.tanh(x @ p["w1"] + p["b1"])
        h = jnp.tanh(h @ p["w2"] + p["b2"])
        return (h @ p["w3"] + p["b3"])[..., 0]

    def fit(self, X, y):
        X = np.asarray(X, np.float32)
        y = np.asarray(y, np.float32)
        self.mu, self.sd = X.mean(0), X.std(0) + 1e-6
        self.ymu, self.ysd = y.mean(), y.std() + 1e-9
        _record_envelope(self, X)
        Xn = (X - self.mu) / self.sd
        yn = (y - self.ymu) / self.ysd
        k = jax.random.PRNGKey(self.seed)
        ks = jax.random.split(k, 3)
        F, H = X.shape[1], self.hidden
        p = {"w1": jax.random.normal(ks[0], (F, H)) / np.sqrt(F),
             "b1": jnp.zeros(H),
             "w2": jax.random.normal(ks[1], (H, H)) / np.sqrt(H),
             "b2": jnp.zeros(H),
             "w3": jax.random.normal(ks[2], (H, 1)) / np.sqrt(H),
             "b3": jnp.zeros(1)}

        def loss(p):
            return jnp.mean((self._apply(p, Xn) - yn) ** 2)

        @jax.jit
        def train(p):
            def body(carry, _):
                p, m = carry
                g = jax.grad(loss)(p)
                m = jax.tree.map(lambda m_, g_: 0.9 * m_ + g_, m, g)
                p = jax.tree.map(lambda p_, m_: p_ - self.lr * m_, p, m)
                return (p, m), None
            m0 = jax.tree.map(jnp.zeros_like, p)
            (p, _), _ = jax.lax.scan(body, (p, m0), None, length=self.steps)
            return p

        self.params = train(p)
        return self

    def predict(self, X) -> np.ndarray:
        Xn = (np.asarray(X, np.float32) - self.mu) / self.sd
        return np.asarray(self._apply(self.params, Xn)) * self.ysd + self.ymu

    def predict_device(self, X):
        """jit-compatible prediction on a jnp feature batch (see
        RandomForestRegressor.predict_device)."""
        Xn = (X.astype(jnp.float32) - self.mu) / self.sd
        return self._apply(self.params, Xn) * self.ysd + self.ymu


# ---------------------------------------------------------------------------
# Sample generation (eq. 12)


def _pad_rows(tree, bucket: int):
    """Pad a stacked pytree's leading axis to `bucket` rows by repeating
    row 0 (rows are independent under vmap/segment_sum, so padded rows are
    inert when their weights are zero)."""
    return jax.tree.map(
        lambda b: jnp.concatenate(
            [b, jnp.broadcast_to(b[:1], (bucket - b.shape[0],)
                                 + b.shape[1:])], axis=0), tree)


@functools.partial(jax.jit, static_argnames=("n_seg",))
def _segment_accumulate(totals, upd, seg, w, *, n_seg):
    """totals[n] += sum over rows with seg == n of w_row * upd_row, per
    leaf. One jitted scatter-reduce per update group."""
    def add(t, u):
        wb = w.reshape((-1,) + (1,) * (u.ndim - 1))
        return t + jax.ops.segment_sum(u * wb, seg, num_segments=n_seg)
    return jax.tree.map(add, totals, upd)


def generate_utility_samples(
        key,
        checkpoints: List,                    # {w^0..w^Imax} pytrees
        client_update_fn: Callable,           # (params, client_idx, rng)->upd
        eval_loss_fn: Callable,               # params -> float
        *,
        num_clients: int,
        n_samples: int = 200,
        s_max: int = 8,
        clients_per_sample: int = 48,
        participate_p=None,
        seed: int = 0,
        batch_fn: Optional[Callable] = None,
        batched_update_fn: Optional[Callable] = None,
        batched_loss_fn: Optional[Callable] = None,
        eval_chunk: int = 64):
    """Returns (features (N,F), targets ΔF (N,)). Each sample: draw i_start
    and a staleness vector over a client subset, apply eq. 12 against the
    checkpoint trajectory and record the loss drop.

    The participation fraction is drawn per sample from U(0.1, 1.0) so the
    regressor sees the full range of aggregation sizes the scheduler will
    encounter (a 2-gradient aggregation moves the model as far as a
    90-gradient one under eq. 4's normalization, but with a far noisier
    direction — the count-utility curve is exactly what û must learn).
    Updates are normalized by the participating count, matching eq. 4.

    When the batched machinery is supplied — ``batch_fn(ci, rng_int)``
    returning the client's training batch (or None for an empty shard),
    ``batched_update_fn(base, stacked_batches)`` (e.g.
    `repro.fl.client.make_batched_client_update`), and
    ``batched_loss_fn(stacked_params) -> (M,) losses`` — generation is
    vectorized on the engine's machinery: sampled client updates are
    grouped by base checkpoint and trained in vmapped jitted calls, and
    the perturbed checkpoints are evaluated in vmapped loss calls instead
    of one host round-trip per sample. The rng draw sequence is shared
    with the loop path, so the integer staleness histograms (and thus the
    features) are identical; targets agree to float tolerance (vmapped
    per-client updates are bit-identical — only the update-sum and loss
    reduction orders differ)."""
    rng = np.random.default_rng(seed)
    Imax = len(checkpoints) - 1
    vectorized = (batch_fn is not None and batched_update_fn is not None
                  and batched_loss_fn is not None)

    # --- draws (one rng stream, identical for both execution paths)
    plans = []   # per sample: (i_start, hist, n_part, any participant)
    items = []   # flattened work list: (sample, base ckpt idx, ci, rng_int)
    for n in range(n_samples):
        i_start = int(rng.integers(min(s_max, Imax - 1) if Imax > s_max
                                   else 0, Imax))
        clients = rng.choice(num_clients, min(clients_per_sample,
                                              num_clients), replace=False)
        s_vec = np.full(len(clients), -1, np.int64)
        p_this = (rng.uniform(0.1, 1.0) if participate_p is None
                  else participate_p)
        part = rng.random(len(clients)) < p_this
        s_vec[part] = rng.integers(0, min(s_max, i_start) + 1,
                                   part.sum())
        n_part = max(int(part.sum()), 1)
        items += [(n, i_start - int(s), int(ci),
                   int(rng.integers(0, 2 ** 31)))
                  for ci, s in zip(clients, s_vec) if s >= 0]
        hist = np.bincount(s_vec[s_vec >= 0], minlength=s_max + 1
                           )[:s_max + 1]
        plans.append((i_start, hist, n_part, bool(part.sum())))

    if not vectorized:
        return _samples_loop(checkpoints, client_update_fn, eval_loss_fn,
                             plans, items)

    # --- vectorized path: train grouped by base checkpoint ...
    totals = jax.tree.map(
        lambda l: jnp.zeros((n_samples,) + np.shape(l),
                            jnp.asarray(l).dtype), checkpoints[0])
    seg_all = np.asarray([it[0] for it in items], np.int32)
    w_all = np.asarray([1.0 / plans[it[0]][2] for it in items], np.float32)
    by_base = {}
    for idx, it in enumerate(items):
        by_base.setdefault(it[1], []).append(idx)
    for base_i, idxs in by_base.items():
        by_shape = {}   # batch-shape signature -> rows (into items)
        for idx in idxs:
            b = batch_fn(items[idx][2], items[idx][3])
            if b is None:        # empty shard: exact-zero update, skip
                continue
            sig = tuple(tuple(np.shape(leaf))
                        for leaf in jax.tree.leaves(b))
            by_shape.setdefault(sig, []).append((idx, b))
        if not by_shape:
            continue
        base = jax.tree.map(jnp.asarray, checkpoints[base_i])
        for mem in by_shape.values():
            m = len(mem)
            bucket = 1 << (m - 1).bit_length()
            # pad with repeats of the first batch BEFORE stacking, so the
            # stacked shapes (and every jit signature downstream) only come
            # in power-of-two buckets — padded rows carry zero weight
            blist = [b for _, b in mem] + [mem[0][1]] * (bucket - m)
            batches = jax.tree.map(lambda *bs: jnp.stack(bs), *blist)
            upd = batched_update_fn(base, batches)
            rows = [idx for idx, _ in mem]
            seg = np.zeros(bucket, np.int32)
            w = np.zeros(bucket, np.float32)
            seg[:m], w[:m] = seg_all[rows], w_all[rows]
            totals = _segment_accumulate(totals, upd, jnp.asarray(seg),
                                         jnp.asarray(w), n_seg=n_samples)

    # --- ... and evaluate every base/perturbed checkpoint in vmapped calls
    i_starts = np.asarray([p[0] for p in plans])
    distinct = sorted(set(int(i) for i in i_starts))
    base_stack = jax.tree.map(lambda *ls: jnp.stack(ls),
                              *[checkpoints[i] for i in distinct])
    T_by = dict(zip(distinct,
                    np.asarray(batched_loss_fn(base_stack), np.float64)))
    lookup = jnp.asarray([distinct.index(int(i)) for i in i_starts],
                         jnp.int32)
    new_loss = np.empty(n_samples, np.float64)
    for c0 in range(0, n_samples, eval_chunk):
        # materialize base + total only per chunk, so eval_chunk really
        # bounds peak device memory on top of the `totals` accumulator
        lk = lookup[c0:c0 + eval_chunk]
        sl = jax.tree.map(
            lambda b, t: jnp.take(b, lk, axis=0) + t[c0:c0 + eval_chunk],
            base_stack, totals)
        m = min(eval_chunk, n_samples - c0)
        if m < eval_chunk:
            sl = _pad_rows(sl, eval_chunk)
        new_loss[c0:c0 + m] = np.asarray(batched_loss_fn(sl))[:m]

    feats, targets = [], []
    for n, (i_start, hist, _, any_part) in enumerate(plans):
        T = float(T_by[i_start])
        d_f = T - float(new_loss[n]) if any_part else 0.0
        feats.append(featurize(hist, T))
        targets.append(d_f)
    return np.stack(feats), np.asarray(targets, np.float32)


def _samples_loop(checkpoints, client_update_fn, eval_loss_fn, plans,
                  items):
    """The seed per-sample/per-client loop (kept as the reference path and
    for callers without batched machinery): one client-update dispatch and
    one host loss evaluation per sample."""
    losses = {}

    def loss_at(i):
        if i not in losses:
            losses[i] = float(eval_loss_fn(checkpoints[i]))
        return losses[i]

    per_sample = [[] for _ in plans]
    for it in items:
        per_sample[it[0]].append(it)
    feats, targets = [], []
    for n, (i_start, hist, n_part, _) in enumerate(plans):
        total_update = None
        for _, base_i, ci, rng_int in per_sample[n]:
            upd = client_update_fn(checkpoints[base_i], ci, rng_int)
            upd = jax.tree.map(lambda x: x / n_part, upd)
            total_update = upd if total_update is None else jax.tree.map(
                lambda a, b: a + b, total_update, upd)
        T = loss_at(i_start)
        if total_update is None:
            d_f = 0.0
        else:
            new = jax.tree.map(lambda w, u: w + u, checkpoints[i_start],
                               total_update)
            d_f = T - float(eval_loss_fn(new))
        feats.append(featurize(hist, T))
        targets.append(d_f)
    return np.stack(feats), np.asarray(targets, np.float32)
