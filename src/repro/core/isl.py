"""Inter-satellite links (ISLs): intra-plane ring topology derived from the
constellation geometry, sink-satellite election, and the device-resident
relay/gossip transitions that compose with the Algorithm-1 protocol steps.

FedSpace's satellites talk only to ground stations; the strongest related
work closes exactly that gap with ISLs. This module implements the two
mechanisms the engine's schedulers build on:

  * **intra-plane propagation with sink satellites** (Razmi et al., arXiv
    2302.13447): satellites in one orbital plane form a ring over
    intra-plane ISLs; per planning epoch, each plane elects the member
    with the earliest (tie: longest) upcoming ground contact as its *sink*,
    every member relays its trained update around the ring toward the
    sink, and the sink uplinks the plane's partial aggregate in one pass.
    Here that is a `relay` hop counter on `repro.core.staleness.SatState`
    (`relay_step`) plus sink-indexed effective connectivity: a member's
    upload becomes GS-visible once its update has accumulated enough hop
    units to have reached the sink, and the whole plane uploads/downloads
    through the sink's contacts.
  * **asynchronous gossip over ISLs** (Razmi et al., arXiv 2206.00307):
    between ground contacts, ring neighbours (optionally grid neighbours
    across planes) exchange models and a satellite that sees a newer
    global version adopts it and restarts local training on it
    (`gossip_step` — the ISL analogue of `download_step`'s
    restart-on-newer-model rule). Uploads still happen at each
    satellite's own physical ground contacts.

`ISLConfig` mirrors `repro.fl.api.LinkConfig`'s zero sentinels: rate or
model size 0 means instantaneous one-window hops; otherwise one ring hop
takes `transfer_windows(isl_mbps, model_mb, T0)` protocol windows. With
``isl=None`` (the default everywhere) none of this exists in the compiled
programs — the `relay` column stays an empty pytree node and every
trajectory is bit-identical to the ground-only protocol.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS
from repro.core.connectivity import (ConstellationSpec, satellite_elements,
                                     transfer_windows)

T0_S = 900.0     # protocol window length (15 min), the hop-latency unit


@dataclass(frozen=True)
class ISLConfig:
    """Declarative ISL options, resolved by `Federation.from_experiment`.

    Zero sentinels mirror `LinkConfig`: `isl_mbps` or `model_mb` 0 makes a
    ring hop instantaneous (one update crosses any ring distance within
    the window it was trained in); both positive make one hop take
    ``transfer_windows(isl_mbps, model_mb, T0)`` windows, so an update
    `d` hops from its sink arrives after ``d * relay_windows`` windows.
    `epoch` is the sink re-election period in windows (2302.13447 re-picks
    the sink per visiting period); `cross_plane` adds grid links to the
    neighbouring planes of the same shell (used by gossip mode, where it
    lets model versions cross planes that never see a ground station)."""
    isl_mbps: float = 0.0      # inter-satellite link rate; 0 = instantaneous
    model_mb: float = 0.0      # model transfer size; 0 = instantaneous
    cross_plane: bool = False  # grid links to adjacent planes (gossip)
    epoch: int = 24            # sink re-election period, windows

    def __post_init__(self):
        if self.isl_mbps < 0:
            raise ValueError(
                f"ISLConfig.isl_mbps must be >= 0, got {self.isl_mbps}")
        if self.model_mb < 0:
            raise ValueError(
                f"ISLConfig.model_mb must be >= 0, got {self.model_mb}")
        if int(self.epoch) < 1:
            raise ValueError(
                f"ISLConfig.epoch must be >= 1, got {self.epoch}")

    @property
    def relay_windows(self) -> int:
        """Windows one ring hop takes (0 = instantaneous sentinel)."""
        return transfer_windows(self.isl_mbps, self.model_mb, T0_S)


@dataclass(frozen=True)
class ISLTopology:
    """Ring (and optional grid) adjacency over a constellation.

    All arrays are (K,) int32, host-side (the engine moves what it needs
    to device once per run). Planes are *physical* orbital planes: the
    satellites sharing a shell, RAAN, inclination, and altitude — so
    single-shell Planet-Flock specs split into sun-synchronous planes and
    ISS-orbit planes exactly as the geometry dictates, and shells never
    mix. Within a plane, satellites are ordered by along-track phase; the
    ring closes over that order. Degenerate planes are self-loops
    (``nxt == prv == self``), which make every ISL transition a no-op for
    them. `left`/`right` are the same-slot members of the adjacent planes
    of the same shell (self when the shell has a single plane)."""
    plane: np.ndarray    # plane id per satellite
    pos: np.ndarray      # ring position within the plane (phase order)
    nxt: np.ndarray      # ring successor (self when alone)
    prv: np.ndarray      # ring predecessor (self when alone)
    left: np.ndarray     # same-slot member of previous plane in shell
    right: np.ndarray    # same-slot member of next plane in shell

    @property
    def num_planes(self) -> int:
        return int(self.plane.max()) + 1 if self.plane.size else 0

    def plane_sizes(self) -> np.ndarray:
        """(num_planes,) member count per plane."""
        return np.bincount(self.plane, minlength=self.num_planes)

    def ring_distance(self, target: np.ndarray) -> np.ndarray:
        """(K,) minimal ring hop count from each satellite to `target[k]`
        (an array of per-satellite targets in the same plane, e.g. the
        elected sinks)."""
        n = self.plane_sizes()[self.plane]
        d = (self.pos - self.pos[target]) % n
        return np.minimum(d, n - d).astype(np.int32)


def _shell_ids(spec: ConstellationSpec) -> np.ndarray:
    """(K,) shell index per satellite (all 0 for single-shell specs)."""
    if spec.shells:
        return np.concatenate(
            [np.full(s.num_satellites, i, np.int32)
             for i, s in enumerate(spec.shells)])
    return np.zeros(spec.num_satellites, np.int32)


def ring_topology(spec: ConstellationSpec) -> ISLTopology:
    """Derive the intra-plane ring (+ cross-plane grid) adjacency from the
    spec's deterministic orbital elements.

    Satellites are grouped into physical planes by (shell, RAAN,
    inclination, altitude) — on the legacy single-shell path this puts the
    ISS-orbit satellites (different inclination/altitude) in their own
    planes, never ringed with the sun-synchronous ones, and multi-shell
    Walker specs decompose into their per-shell planes. The grouping is a
    pure function of the spec, like everything else in the geometry layer.
    """
    raan, inc, phase, alt = satellite_elements(spec)
    shell = _shell_ids(spec)
    key = np.stack([shell.astype(np.float64), np.round(raan, 9),
                    np.round(inc, 9), np.round(alt, 3)], axis=1)
    _, plane = np.unique(key, axis=0, return_inverse=True)
    plane = plane.astype(np.int32)
    K = plane.shape[0]
    pos = np.zeros(K, np.int32)
    nxt = np.arange(K, dtype=np.int32)
    prv = np.arange(K, dtype=np.int32)
    members = {}                     # plane id -> members in ring order
    for p in np.unique(plane):
        m = np.flatnonzero(plane == p)
        order = m[np.lexsort((m, phase[m]))]
        members[int(p)] = order
        pos[order] = np.arange(order.size)
        if order.size > 1:
            nxt[order] = np.roll(order, -1)
            prv[order] = np.roll(order, 1)
    left, right = _grid_neighbors(shell, plane, raan, members)
    return ISLTopology(plane=plane, pos=pos, nxt=nxt, prv=prv,
                       left=left, right=right)


def _grid_neighbors(shell, plane, raan, members):
    """Same-slot links to the adjacent planes of the same shell (RAAN
    order, wrapping), self where the shell has a single plane. Slot r of a
    plane maps to slot ``r % n`` of a differently-sized neighbour."""
    K = plane.shape[0]
    left = np.arange(K, dtype=np.int32)
    right = np.arange(K, dtype=np.int32)
    for s in np.unique(shell):
        pids = np.unique(plane[shell == s])
        order = pids[np.argsort([raan[members[int(p)][0]] for p in pids],
                                kind="stable")]
        if order.size < 2:
            continue
        for j, p in enumerate(order):
            mine = members[int(p)]
            for arr, q in ((left, order[(j - 1) % order.size]),
                           (right, order[(j + 1) % order.size])):
                other = members[int(q)]
                arr[mine] = other[np.arange(mine.size) % other.size]
    return left, right


def identity_topology(K: int) -> ISLTopology:
    """The degenerate no-ISL topology — every satellite its own singleton
    plane, every link a self-loop. Under it, sink election picks each
    satellite as its own sink and every relay arrives in place, so an
    ISL-enabled run must reproduce the plain ground-only protocol
    bit-for-bit (the parity gate in `benchmarks/hotpaths.py` and
    `tests/test_isl.py` runs exactly this)."""
    idx = np.arange(K, dtype=np.int32)
    return ISLTopology(plane=idx.copy(), pos=np.zeros(K, np.int32),
                       nxt=idx.copy(), prv=idx.copy(), left=idx.copy(),
                       right=idx.copy())


@dataclass(frozen=True)
class ISL:
    """Resolved ISL runtime handed to the engine and the schedulers:
    the derived topology plus the `ISLConfig`-resolved hop latency and
    election period. Built by `build_isl` (via
    `repro.fl.api.Federation.from_experiment` when `FLExperiment.isl`
    is set)."""
    topology: ISLTopology
    relay_windows: int = 0
    epoch: int = 24
    cross_plane: bool = False

    def sink_plan(self, C_epoch: np.ndarray, *, alive=None):
        """Sinks and per-satellite hop needs for one election epoch:
        returns ``(sink (K,), need_hops (K,))`` from the epoch's effective
        connectivity slice (`elect_sinks` + ring distances scaled by the
        hop latency; instantaneous hops need 0). `alive` (fault runs)
        restricts the election to satellites alive at some point in the
        epoch — a deorbited member must not be elected sink."""
        sink = elect_sinks(C_epoch, self.topology, alive=alive)
        need = self.topology.ring_distance(sink) * self.relay_windows
        return sink, need.astype(np.int32)


def build_isl(spec: ConstellationSpec, config: ISLConfig) -> ISL:
    """Resolve an `ISLConfig` against a constellation spec."""
    return ISL(topology=ring_topology(spec),
               relay_windows=config.relay_windows,
               epoch=max(int(config.epoch), 1),
               cross_plane=config.cross_plane)


def elect_sinks(C_epoch: np.ndarray, topo: ISLTopology, *,
                alive=None) -> np.ndarray:
    """Per-plane sink election (2302.13447 §III): the member whose next
    ground contact in the epoch comes earliest wins; ties go to the member
    with the most contact windows in the epoch, then the lowest satellite
    index. Planes with no contact in the epoch elect their lowest-index
    member (their ring still relays, it just never reaches ground until a
    later epoch's election sees a contact).

    Args:
      C_epoch: (W, K) bool — the epoch's (effective) connectivity slice.
      topo: the ring topology whose `plane` grouping scopes the election.
      alive: optional (K,) bool candidate mask (`repro.core.faults`):
        dead satellites are never elected; an all-dead plane falls back to
        the full membership (its election is moot — no member can act).

    Returns (K,) int32: each satellite's elected sink (same plane always).
    """
    C_epoch = np.asarray(C_epoch, bool)
    W = C_epoch.shape[0]
    has = C_epoch.any(axis=0)
    first = np.where(has, C_epoch.argmax(axis=0), W)     # W = "never"
    total = C_epoch.sum(axis=0)
    sink = np.empty(topo.plane.shape[0], np.int32)
    alive = None if alive is None else np.asarray(alive, bool)
    for p in np.unique(topo.plane):
        m = np.flatnonzero(topo.plane == p)
        cand = m if alive is None else m[alive[m]]
        if cand.size == 0:
            cand = m
        best = cand[np.lexsort((cand, -total[cand], first[cand]))][0]
        sink[m] = best
    return sink


# ---------------------------------------------------------------------------
# Device-resident ISL transitions. Pure jnp over SatState, composable with
# upload_step/aggregate_step/download_step inside the engine's jitted scan
# (repro.fl.engine._scan_windows) and its per-window host-loop wrappers —
# one transition semantics for both execution strategies, like the rest of
# the protocol.


def relay_step(state: SS.SatState, need_hops):
    """Advance the intra-ring relay by one window: every satellite holding
    a pending update accumulates one hop unit toward its sink. Returns
    ``(state, arrived)`` where ``arrived[k]`` means k's update has covered
    its ring distance (``relay >= need_hops``; distance-0 satellites —
    sinks, and everyone under instantaneous hops — arrive immediately).

    The counter resets on download (`reset_relay`) when the satellite
    starts its next local round, so `relay` measures transit of the
    *current* pending update. Re-elections mid-transit keep the
    accumulated units (the partial aggregate is already moving along the
    ring; 2302.13447 re-targets it rather than restarting)."""
    relay = state.relay + (state.pending >= 0).astype(state.relay.dtype)
    return state._replace(relay=relay), relay >= need_hops


def reset_relay(state: SS.SatState, downloads):
    """Zero the relay counter where a download started a fresh local round
    (the new pending update begins its ring transit from scratch)."""
    return state._replace(
        relay=jnp.where(downloads, 0, state.relay))


def sink_connectivity(conn, sink, arrived, pending, *, axis_name=None):
    """Effective connectivity under sink relaying: satellite k can reach
    the GS this window iff its plane's sink has a (served) contact AND
    k's update has arrived at the sink — or k has nothing in transit
    (idle / download-only contacts ride the sink's pass directly, the
    ring broadcast of the global model being pipelined within the
    window).

    `sink` holds *global* satellite indices; when the satellite axis is
    sharded (`axis_name`, see `repro.core.mesh`) the connectivity row is
    `all_gather`ed — one (K,) bool row per window — so each shard can
    look up its plane's sink wherever it lives."""
    if axis_name is not None:
        conn = jax.lax.all_gather(conn, axis_name, tiled=True)
    return conn[sink] & (arrived | (pending < 0))


def gossip_step(state: SS.SatState, nxt, prv, left, right, do_hop,
                alive=None, *, axis_name=None):
    """One asynchronous intra-ring gossip exchange (2206.00307): each
    satellite looks at its ring neighbours (and grid neighbours, which are
    self-loops unless cross-plane links are configured) and, when `do_hop`
    is set and a neighbour holds a newer global version, adopts it and
    restarts local training on it — exactly `download_step`'s
    restart-on-newer-model rule, with the neighbour in place of the GS.
    Version-equal neighbours exchange nothing the protocol state can see
    (model *averaging* between same-version peers does not change
    version/pending/staleness bookkeeping), so the transition tracks
    propagation, which is what staleness/idleness accounting needs.

    `alive` (fault runs, (K,) bool) removes dead satellites from the
    exchange entirely: they offer nothing to their neighbours (their
    version reads as -1, below any live version) and adopt nothing
    themselves. `alive=None` compiles the exact prior program.

    The neighbour arrays hold *global* satellite indices; when the
    satellite axis is sharded (`axis_name`) the masked version vector is
    `all_gather`ed — one (K,) int row per hop — before the four gathers,
    so ring/grid neighbours resolve across shard boundaries.

    Returns ``(state, adopted)`` with the adoption mask."""
    v = state.version
    vn = v if alive is None else jnp.where(alive, v, SS._m1(v))
    if axis_name is not None:
        vn = jax.lax.all_gather(vn, axis_name, tiled=True)
    nbv = jnp.maximum(jnp.maximum(vn[nxt], vn[prv]),
                      jnp.maximum(vn[left], vn[right]))
    adopted = do_hop & (nbv > v)
    if alive is not None:
        adopted = adopted & alive
    return state._replace(version=jnp.where(adopted, nbv, v),
                          pending=jnp.where(adopted, nbv, state.pending)), \
        adopted


def reachable_count(topo: ISLTopology, C: np.ndarray) -> int:
    """Number of satellites in planes with at least one (effective) ground
    contact over the run — the natural sync threshold for sink-relay
    scheduling (planes that never see a station can never contribute, so
    waiting for all K would deadlock e.g. mid-inclination Starlink shells
    over a polar-only ground network)."""
    has = np.asarray(C, bool).any(axis=0)
    reach = np.unique(topo.plane[has])
    return int(np.isin(topo.plane, reach).sum())
