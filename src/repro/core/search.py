"""Random search over aggregation-schedule candidates (paper §3.2, eq. 13).

The search space R ⊂ {0,1}^{I0} is restricted to schedules with
n_agg ∈ [N_min, N_max] aggregations (the paper infers the range from û and
uses |R| = 5000). Candidate evaluation is the vectorized protocol simulator
(repro.core.staleness.simulate_candidates) — one vmapped scan instead of the
paper's sequential Python loop.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as MM
from repro.core import staleness as SS
from repro.core.utility import featurize, featurize_jnp


def random_candidates(rng: np.random.Generator, I0: int, n_min: int,
                      n_max: int, R: int) -> np.ndarray:
    """(R, I0) binary matrix; row r has n_r ~ U[n_min, n_max] ones."""
    n_min = max(0, min(n_min, I0))
    n_max = max(n_min, min(n_max, I0))
    scores = rng.random((R, I0))
    n_agg = rng.integers(n_min, n_max + 1, R)
    order = np.argsort(scores, axis=1)
    ranks = np.empty_like(order)
    rows = np.arange(R)[:, None]
    ranks[rows, order] = np.arange(I0)[None, :]
    return (ranks < n_agg[:, None]).astype(np.int32)


def event_positions(candidates: np.ndarray):
    """Per-candidate aggregation-window indices, dense (host numpy).

    Returns (idx, mask): idx (R, n_cap) int32 holds each schedule's a=1
    window indices in increasing order (n_cap = max aggregation count over
    the batch, at least 1), 0-padded; mask (R, n_cap) bool flags the real
    entries. The eq.-13 objective only sums utility at a=1 windows, so the
    scorer evaluates û at these positions instead of all I0 windows.
    """
    cands = np.asarray(candidates)
    n = cands.sum(axis=1).astype(np.int64)
    n_cap = max(int(n.max()) if n.size else 0, 1)
    # stable argsort of (1 - a) lists the a=1 positions first, in order
    idx = np.argsort(1 - cands, axis=1, kind="stable")[:, :n_cap]
    mask = np.arange(n_cap)[None, :] < n[:, None]
    return idx.astype(np.int32), mask


@functools.partial(jax.jit, static_argnames=("s_max", "mesh"))
def _simulate_marks(C_window, candidates, state, ig, link, *, s_max: int,
                    mesh=None):
    """Jitted marks-collecting candidate simulation (the eager vmapped
    scan pays ~3x its own runtime in dispatch overhead at search shapes).
    `link` is an optional device `LinkGate` (grant (I0, K)) so candidates
    are scored against transfer-gated effective connectivity.

    `mesh` (static — meshes hash) shards the satellite axis of the
    vmapped scan under `shard_map`: state columns and the K axes of the
    connectivity/grant windows split across devices, candidates and
    scalars replicate, and the only cross-shard traffic is the
    empty-buffer psum inside `aggregate_step` (the marks themselves are
    per-satellite). The caller pads K to a device-count multiple
    (`score_candidates` does); `mesh=None` compiles the exact
    single-device program."""
    def run(Cw, cands, st, g, lk, axis=None):
        _, _, infos = SS.simulate_candidates(Cw, cands, st, g,
                                             s_max=s_max, collect="marks",
                                             link=lk, axis_name=axis)
        return infos["marks"]

    if mesh is None:
        return run(C_window, candidates, state, ig, link)
    ax = mesh.axis_names[0]
    P = jax.sharding.PartitionSpec
    sat, rep, col = P(ax), P(), P(None, ax)
    link_spec = rep if link is None else SS.LinkGate(col, rep, rep)
    return MM.shard_map(
        functools.partial(run, axis=ax), mesh,
        in_specs=(col, rep, sat, rep, link_spec),
        out_specs=P(None, None, ax))(C_window, candidates, state, ig,
                                     link)


@functools.partial(jax.jit, static_argnames=("s_max",))
def _simulate_marks_state(C_window, candidates, state, ig, link, *,
                          s_max: int):
    """`_simulate_marks` variant that also returns each candidate's final
    scan state and global version — the per-candidate frontier the
    incremental replanner (`repro.fl.replan.ReplanService`) caches so the
    next replan can simulate only the newly revealed window. The marks
    themselves are value-identical to `_simulate_marks` (same transitions,
    extra outputs), which is what keeps delta-scored schedules bit-equal
    to a full rescan. Single-device only: the replan cache is not built
    under a satellite-axis mesh (`score_candidates(mesh=...)` remains the
    sharded full-rescan path)."""
    fstate, fig, infos = SS.simulate_candidates(C_window, candidates,
                                                state, ig, s_max=s_max,
                                                collect="marks", link=link)
    return infos["marks"], fstate, fig


@functools.partial(jax.jit, static_argnames=("s_max",))
def step_candidates(states, igs, connected, bits, link, *, s_max: int):
    """One protocol window vmapped over per-candidate *states* — the
    delta-scan transition for a newly revealed window.

    `_simulate_marks` vmaps candidate schedules over one shared initial
    state; here every candidate carries its own frontier state/version
    (the scan state cached from the previous replan), takes its own
    aggregation bit for the revealed window, and shares the window's
    connectivity column and link gate. Built on the same
    `repro.core.staleness.step` composition as the scan, so the emitted
    marks — and the advanced states — are bit-identical to what a full
    rescan would compute at its last window.

    Args:
      states: stacked `SatState`, leading axis R (any signed-int dtype).
      igs: (R,) per-candidate global version, same dtype as the states.
      connected: (K,) bool — the revealed window's connectivity column.
      bits: (R,) {0,1} — each candidate's aggregation bit at that window.
      link: optional `LinkGate` with a (K,) grant shared by every
        candidate, or None.
      s_max: staleness clip (static).

    Returns (marks (R, K), new_states, new_igs).
    """
    def one(st, g, a):
        return SS.step(st, g, connected, a.astype(bool), s_max=s_max,
                       collect="marks", link=link)

    st, g, info = jax.vmap(one)(states, igs, bits)
    return info["marks"], st, g


@functools.partial(jax.jit, static_argnames=("s_max",))
def _event_features(marks, idx, status, *, s_max: int):
    """Gather the (R, I0, K) staleness marks at each candidate's
    aggregation windows, histogram them, and featurize: (R*n_cap, F)
    features for the utility regressor. The one-hot reduction runs once
    over the gathered events — n_agg of I0 windows — instead of inside the
    per-step scan, and accumulates in int16 (exact for K < 32768)."""
    g = jnp.take_along_axis(marks, idx[..., None], axis=1)  # (R, n_cap, K)
    hists = SS.hist_from_marks(g, s_max=s_max, dtype=jnp.int16)
    Rn, n_cap, F = hists.shape
    return featurize_jnp(hists.reshape(Rn * n_cap, F), status)


def _narrow_state(state: SS.SatState, ig: int, horizon: int):
    """int16 copy of (state, ig) when every version the window can produce
    fits — on CPU the narrowed vmapped scan moves half the bytes and runs
    ~3x faster, with bit-identical marks. Falls back to int32 otherwise.
    The `progress` and `relay` columns (if attached) stay int32: their
    arithmetic only meets int32 grant/need/hop scalars, never the version
    fields."""
    if ig + horizon < np.iinfo(np.int16).max - 1:
        dt = jnp.int16
    else:
        dt = jnp.int32
    return (SS.SatState(*(x.astype(dt) for x in state[:3]), state.progress,
                        state.relay),
            jnp.asarray(ig, dt))


def score_candidates(candidates: np.ndarray, C_window: np.ndarray,
                     state: SS.SatState, ig: int, regressor, status: float,
                     *, s_max: int = 8, chunk_rows: Optional[int] = None,
                     link: Optional[SS.LinkGate] = None,
                     mesh=None) -> np.ndarray:
    """Predicted summed utility per candidate (eq. 13).

    When the regressor exposes `predict_device` (both built-in regressors
    do), the whole pipeline stays on device and scatter/broadcast-free:
    the vmapped protocol scan carries only masked `jnp.where` updates over
    the dense per-satellite state (int16-narrowed) and emits compact
    staleness marks; histograms, featurization, and regression run once
    post-scan at each candidate's aggregation windows only (a=0 windows
    contribute exactly 0 to eq. 13). The only host transfer is the final
    (R,) score vector. Regressors with only `.predict` (e.g. test oracles)
    fall back to the legacy full-histogram host path.

    Args:
      candidates: (R, I0) {0,1} schedules to score.
      C_window: (I0, K) bool future connectivity.
      state, ig: post-upload protocol state at the window start.
      regressor: utility model û; `predict_device` selects the fast path.
      status: training status T fed to the featurizer.
      s_max: staleness clip — must match the regressor's feature width.
      chunk_rows: candidates simulated per device batch (None = auto-sized
        so the marks buffer stays ~64 MB); chunking only bounds memory,
        per-candidate results are unchanged.
      link: optional `LinkGate` (grant (I0, K), any array-like) gating the
        simulated transfers, so candidates are scored against effective —
        capacity-constrained — connectivity rather than raw visibility;
        `state.progress` must be attached when given.
      mesh: optional satellite-axis device mesh (`repro.core.mesh`): the
        fast path pads K to a device-count multiple with never-connected
        satellites (whose marks stay -1, invisible to the histograms) and
        shards the vmapped scan via `shard_map` — scores are bit-identical
        to `mesh=None`, which compiles the exact single-device program.
        The legacy `.predict` fallback ignores it.

    Returns: (R,) float32 predicted utility sums.
    """
    if link is not None:
        link = SS.LinkGate(jnp.asarray(np.asarray(link.grant), jnp.int32),
                           jnp.int32(link.need_up), jnp.int32(link.need_dn))
    predict_device = getattr(regressor, "predict_device", None)
    if predict_device is None:
        cands = jnp.asarray(candidates)
        Cw = jnp.asarray(C_window)
        # s_max must reach the simulator so the staleness histograms match
        # the regressor's feature width; only the histograms are consumed
        _, _, infos = SS.simulate_candidates(Cw, cands, state,
                                             jnp.int32(ig), s_max=s_max,
                                             lite=True, link=link)
        hist = np.asarray(infos["hist"])                 # (R, I0, s_max+1)
        Rn, I0, F = hist.shape
        feats = featurize(hist.reshape(Rn * I0, F), status)
        util = regressor.predict(feats).reshape(Rn, I0)
        agg_mask = np.asarray(candidates, np.float32)
        return (util * agg_mask).sum(axis=1)

    cands = np.asarray(candidates)
    R, I0 = cands.shape
    K = C_window.shape[1]
    idx, mask = event_positions(cands)
    C_window = np.asarray(C_window, bool)
    if mesh is not None:
        Kp = MM.padded_size(K, mesh)
        C_window = MM.pad_axis(C_window, Kp)
        state = MM.pad_state(state, Kp)
        if link is not None:
            link = link._replace(grant=jnp.asarray(
                MM.pad_axis(np.asarray(link.grant), Kp)))
    Cw = jnp.asarray(C_window)
    st, igd = _narrow_state(state, int(ig), I0)
    if chunk_rows is None:
        chunk_rows = max(256, (64 << 20) // max(I0 * K, 1))
    scores = np.empty(R, np.float32)
    for c0 in range(0, R, chunk_rows):
        rows = slice(c0, min(c0 + chunk_rows, R))
        marks = _simulate_marks(Cw, jnp.asarray(cands[rows]), st, igd,
                                link, s_max=s_max, mesh=mesh)
        feats = _event_features(marks, jnp.asarray(idx[rows]),
                                jnp.float32(status), s_max=s_max)
        util = predict_device(feats).reshape(-1, idx.shape[1])
        scores[rows] = np.asarray(
            (util * jnp.asarray(mask[rows], jnp.float32)).sum(axis=1))
    return scores


def scan_candidates(candidates: np.ndarray, C_window: np.ndarray,
                    state: SS.SatState, ig: int, regressor, status: float,
                    *, s_max: int = 8, chunk_rows: Optional[int] = None,
                    link: Optional[SS.LinkGate] = None):
    """`score_candidates`' device pipeline, additionally materializing the
    per-candidate scan artifacts the incremental replanner caches
    (`repro.fl.replan.ReplanService` — see `docs/replanning.md`).

    Scores are bit-identical to `score_candidates` on the same inputs:
    the marks come from the same transitions (`_simulate_marks_state` only
    adds outputs), the per-event utilities from the same
    histogram/featurize/predict pipeline, and the final masked reduction
    runs at the same (R, n_cap) shape. The regressor must expose
    `predict_device` (there is no legacy `.predict` fallback here — a
    host-path regressor has no cacheable device artifacts).

    Returns (scores (R,) float32, artifacts) where artifacts is a dict:
      win_util: (R, I0) float32 — each candidate's predicted per-event
        utility placed at its aggregation offsets (0 elsewhere; padded
        event slots land on a=0 offsets by construction, so real events
        are never overwritten).
      end_state: host-numpy stacked `SatState`, leading axis R — each
        candidate's scan state after the last window (the frontier the
        next delta step advances from).
      end_ig: (R,) per-candidate final global version (scan dtype).
      state_dtype: the narrowed scan dtype (np.int16 or np.int32) — the
        delta path's narrowing-guard check compares against it.
    """
    cands = np.asarray(candidates)
    R, I0 = cands.shape
    K = C_window.shape[1]
    if link is not None:
        link = SS.LinkGate(jnp.asarray(np.asarray(link.grant), jnp.int32),
                           jnp.int32(link.need_up), jnp.int32(link.need_dn))
    idx, mask = event_positions(cands)
    Cw = jnp.asarray(np.asarray(C_window, bool))
    st, igd = _narrow_state(state, int(ig), I0)
    if chunk_rows is None:
        chunk_rows = max(256, (64 << 20) // max(I0 * K, 1))
    scores = np.empty(R, np.float32)
    win_util = np.zeros((R, I0), np.float32)
    end_states, end_igs = [], []
    predict_device = regressor.predict_device
    for c0 in range(0, R, chunk_rows):
        rows = slice(c0, min(c0 + chunk_rows, R))
        marks, fstate, fig = _simulate_marks_state(
            Cw, jnp.asarray(cands[rows]), st, igd, link, s_max=s_max)
        feats = _event_features(marks, jnp.asarray(idx[rows]),
                                jnp.float32(status), s_max=s_max)
        util = predict_device(feats).reshape(-1, idx.shape[1])
        masked = util * jnp.asarray(mask[rows], jnp.float32)
        scores[rows] = np.asarray(masked.sum(axis=1))
        np.put_along_axis(win_util[rows], idx[rows], np.asarray(masked),
                          axis=1)
        end_states.append(jax.tree.map(np.asarray, fstate))
        end_igs.append(np.asarray(fig))
    end_state = jax.tree.map(lambda *xs: np.concatenate(xs), *end_states)
    return scores, {"win_util": win_util, "end_state": end_state,
                    "end_ig": np.concatenate(end_igs),
                    "state_dtype": np.dtype(np.int16)
                    if st.version.dtype == jnp.int16
                    else np.dtype(np.int32)}


def infer_n_range(regressor, uploads_per_window: float, I0: int,
                  status: float, *, s_max: int = 8, K: int = None,
                  halfwidth: int = 4):
    """Infer [N_min, N_max] from û, as the paper does: for each candidate
    aggregation count n, approximate the per-aggregation staleness histogram
    under even spacing (uploads split across n aggregations, mostly fresh),
    and pick the count maximizing n * û(hist(n), T)."""
    # Cap at one aggregation per two windows: beyond that per-aggregation
    # buffers thin out into the async regime the paper shows fails, and û
    # extrapolates badly at counts it never sampled.
    n_cap = max(1, I0 // 2)
    total_uploads = uploads_per_window * I0
    # f64 like the scalar loop this replaces (the f32 store happens once,
    # on assignment into hists), so the histogram features — and thus the
    # forest-split decisions — are bit-identical to the seed path
    ns = np.arange(1, n_cap + 1, dtype=np.float64)
    per = total_uploads / ns
    if K:
        per = np.minimum(per, K)
    hists = np.zeros((n_cap, s_max + 1), np.float32)
    hists[:, 0] = per * 0.7          # even spacing: gradients mostly fresh
    hists[:, 1] = per * 0.3
    u = ns * regressor.predict(featurize(hists, status)).astype(np.float64)
    best_n = 1 + int(np.argmax(u))
    return max(1, best_n - halfwidth), min(n_cap, best_n + halfwidth)


def fedspace_search(rng: np.random.Generator, C_window: np.ndarray,
                    state: SS.SatState, ig: int, regressor, status: float,
                    *, n_min: int = 4, n_max: int = 8, num_candidates: int
                    = 5000, s_max: int = 8,
                    link: Optional[SS.LinkGate] = None,
                    mesh=None) -> np.ndarray:
    I0 = C_window.shape[0]
    cands = random_candidates(rng, I0, n_min, n_max, num_candidates)
    scores = score_candidates(cands, C_window, state, ig, regressor, status,
                              s_max=s_max, link=link, mesh=mesh)
    return cands[select_candidate(cands, scores)]


def select_candidate(cands: np.ndarray, scores: np.ndarray) -> int:
    """Index of the winning candidate. Distinct-but-equivalent candidates
    (identical staleness histograms) tie at float level, and different
    scoring backends (host numpy vs on-device) break such ties differently
    by reduction-order jitter; so among candidates within float noise of
    the max, pick the lexicographically smallest schedule — deterministic
    and backend-stable."""
    best = float(np.max(scores))
    eps = 32 * float(np.finfo(np.float32).eps) * max(1.0, abs(best))
    near = np.flatnonzero(scores >= best - eps)
    if near.size > 1:
        near = sorted(near, key=lambda j: cands[j].tobytes())
    return int(near[0])
