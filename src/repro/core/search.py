"""Random search over aggregation-schedule candidates (paper §3.2, eq. 13).

The search space R ⊂ {0,1}^{I0} is restricted to schedules with
n_agg ∈ [N_min, N_max] aggregations (the paper infers the range from û and
uses |R| = 5000). Candidate evaluation is the vectorized protocol simulator
(repro.core.staleness.simulate_candidates) — one vmapped scan instead of the
paper's sequential Python loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS
from repro.core.utility import featurize, featurize_jnp


def random_candidates(rng: np.random.Generator, I0: int, n_min: int,
                      n_max: int, R: int) -> np.ndarray:
    """(R, I0) binary matrix; row r has n_r ~ U[n_min, n_max] ones."""
    n_min = max(0, min(n_min, I0))
    n_max = max(n_min, min(n_max, I0))
    scores = rng.random((R, I0))
    n_agg = rng.integers(n_min, n_max + 1, R)
    order = np.argsort(scores, axis=1)
    ranks = np.empty_like(order)
    rows = np.arange(R)[:, None]
    ranks[rows, order] = np.arange(I0)[None, :]
    return (ranks < n_agg[:, None]).astype(np.int32)


def score_candidates(candidates: np.ndarray, C_window: np.ndarray,
                     state: SS.SatState, ig: int, regressor, status: float,
                     *, s_max: int = 8) -> np.ndarray:
    """Predicted summed utility per candidate (eq. 13).

    When the regressor exposes `predict_device` (both built-in regressors
    do), the whole pipeline — protocol simulation, featurization, regression,
    masked reduction — stays on device; the only host transfer is the final
    (R,) score vector. Regressors with only `.predict` (e.g. test oracles)
    fall back to the host path.
    """
    cands = jnp.asarray(candidates)
    Cw = jnp.asarray(C_window)
    # s_max must reach the simulator so the staleness histograms match
    # the regressor's feature width; only the histograms are consumed
    _, _, infos = SS.simulate_candidates(Cw, cands, state, jnp.int32(ig),
                                         s_max=s_max, lite=True)
    predict_device = getattr(regressor, "predict_device", None)
    if predict_device is not None:
        hist = infos["hist"]                             # (R, I0, s_max+1)
        Rn, I0, F = hist.shape
        feats = featurize_jnp(hist.reshape(Rn * I0, F), status)
        util = predict_device(feats).reshape(Rn, I0)
        return np.asarray((util * cands.astype(jnp.float32)).sum(axis=1))
    hist = np.asarray(infos["hist"])                     # (R, I0, s_max+1)
    Rn, I0, F = hist.shape
    feats = featurize(hist.reshape(Rn * I0, F), status)
    util = regressor.predict(feats).reshape(Rn, I0)
    agg_mask = candidates.astype(np.float32)
    return (util * agg_mask).sum(axis=1)


def infer_n_range(regressor, uploads_per_window: float, I0: int,
                  status: float, *, s_max: int = 8, K: int = None,
                  halfwidth: int = 4):
    """Infer [N_min, N_max] from û, as the paper does: for each candidate
    aggregation count n, approximate the per-aggregation staleness histogram
    under even spacing (uploads split across n aggregations, mostly fresh),
    and pick the count maximizing n * û(hist(n), T)."""
    # Cap at one aggregation per two windows: beyond that per-aggregation
    # buffers thin out into the async regime the paper shows fails, and û
    # extrapolates badly at counts it never sampled.
    n_cap = max(1, I0 // 2)
    total_uploads = uploads_per_window * I0
    # f64 like the scalar loop this replaces (the f32 store happens once,
    # on assignment into hists), so the histogram features — and thus the
    # forest-split decisions — are bit-identical to the seed path
    ns = np.arange(1, n_cap + 1, dtype=np.float64)
    per = total_uploads / ns
    if K:
        per = np.minimum(per, K)
    hists = np.zeros((n_cap, s_max + 1), np.float32)
    hists[:, 0] = per * 0.7          # even spacing: gradients mostly fresh
    hists[:, 1] = per * 0.3
    u = ns * regressor.predict(featurize(hists, status)).astype(np.float64)
    best_n = 1 + int(np.argmax(u))
    return max(1, best_n - halfwidth), min(n_cap, best_n + halfwidth)


def fedspace_search(rng: np.random.Generator, C_window: np.ndarray,
                    state: SS.SatState, ig: int, regressor, status: float,
                    *, n_min: int = 4, n_max: int = 8, num_candidates: int
                    = 5000, s_max: int = 8) -> np.ndarray:
    I0 = C_window.shape[0]
    cands = random_candidates(rng, I0, n_min, n_max, num_candidates)
    scores = score_candidates(cands, C_window, state, ig, regressor, status,
                              s_max=s_max)
    return cands[select_candidate(cands, scores)]


def select_candidate(cands: np.ndarray, scores: np.ndarray) -> int:
    """Index of the winning candidate. Distinct-but-equivalent candidates
    (identical staleness histograms) tie at float level, and different
    scoring backends (host numpy vs on-device) break such ties differently
    by reduction-order jitter; so among candidates within float noise of
    the max, pick the lexicographically smallest schedule — deterministic
    and backend-stable."""
    best = float(np.max(scores))
    eps = 32 * float(np.finfo(np.float32).eps) * max(1.0, abs(best))
    near = np.flatnonzero(scores >= best - eps)
    if near.size > 1:
        near = sorted(near, key=lambda j: cands[j].tobytes())
    return int(near[0])
