"""Aggregation schedulers — the indicator a^i policies of Algorithm 1.

Sync (eq. 5), Async (eq. 6), FedBuff (eq. 7), and FedSpace (§3), all behind
one interface so the FL simulation engine (repro.fl.simulation) is
policy-agnostic.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS
from repro.core.search import fedspace_search
from repro.fl.registry import SCHEDULERS, register_scheduler


# Device-side aggregation indicators, consumed inside the engine's jitted
# window scan. Module-level (stable identity) so jit caches one program per
# scheduler kind, not per scheduler instance; instance knobs (K, M, the
# FedSpace schedule) travel as the `args` array pytree instead.

def _sync_indicator(t, n_buf, args):
    return n_buf >= args                       # args = K


def _async_indicator(t, n_buf, args):
    return n_buf > 0


def _fedbuff_indicator(t, n_buf, args):
    return n_buf >= args                       # args = M


def _periodic_indicator(t, n_buf, args):
    return (n_buf > 0) & ((t + 1) % args == 0)  # args = period


def _fedspace_indicator(t, n_buf, args):
    sched, start = args
    return sched[t - start] > 0


class Scheduler:
    """Aggregation-policy interface: the indicator a^i of Algorithm 1.

    A scheduler answers one question per window — "aggregate now?" — via
    `decide`, and may additionally offer `device_plan` so the engine can
    answer it inside a jitted scan without per-window Python dispatch.
    Schedulers are registered by name (`repro.fl.registry.SCHEDULERS`)
    and built with `make_scheduler`.

    `isl_mode` declares which ISL transition the scheduler's policy is
    built on — ``"sink"`` (intra-plane relay toward elected sink
    satellites), ``"gossip"`` (asynchronous intra-ring version exchange),
    or None (ground-only, the default). The engine activates the declared
    mode only when the run also carries a resolved ISL runtime
    (`repro.core.isl.ISL`, from `FLExperiment.isl`), and then sets the
    `isl` instance attribute before `reset()` so the scheduler can read
    the topology; ground-only schedulers under an ISL-configured
    experiment keep running the unmodified protocol, which is what makes
    with/without-ISL comparisons share one world.
    """
    name = "base"
    isl_mode = None      # "sink" | "gossip" | None (ground-only)
    isl = None           # resolved repro.core.isl.ISL, set by the engine
    # the run's satellite-axis device mesh (repro.core.mesh), set by the
    # engine before reset(); schedulers that run device-side simulation
    # (fedspace's eq.-13 search) shard it over the same mesh as the run
    mesh = None

    def reset(self):
        """Clear per-run state. The engine calls this once in `prepare()`;
        stateless schedulers need not override it."""
        pass

    def decide(self, i: int, *, n_in_buffer: int, K: int, state: SS.SatState,
               ig: int, connectivity: np.ndarray, status: float,
               link=None) -> bool:
        """The aggregation indicator a^i, asked once per window on the
        host loop (after the window's uploads).

        Args:
          i: absolute window index.
          n_in_buffer: GS buffer occupancy after this window's uploads.
          K: constellation size.
          state: the device-resident post-upload `SatState` (read-only).
          ig: current global version.
          connectivity: the full (num_windows, K) bool matrix — FedSpace
            slices the *future* window from it (deterministic, eq. 2).
            Under a link budget this is the *effective* capacity-resolved
            matrix, so schedule searches see what transfers can actually
            complete, not raw visibility.
          status: training status T (val loss at the last eval).
          link: run-level `repro.core.staleness.LinkGate` (grant
            (num_windows, K) host array + unit needs) when the engine
            models link budgets, else None. Schedulers that simulate the
            future (FedSpace) must gate their simulation with it.

        Returns True to aggregate at this window (the engine additionally
        requires a non-empty buffer).
        """
        raise NotImplementedError

    def device_plan(self, i: int, *, K: int, state: SS.SatState, ig: int,
                    connectivity: np.ndarray, status: float, link=None,
                    **_):
        """Fast-path hook for the device-resident engine: return
        ``(indicator_fn, args, horizon)`` where ``indicator_fn(t, n_buf,
        args) -> bool`` is jnp-traceable and decides a^t (t absolute window
        index, n_buf the post-upload buffer occupancy) for every window in
        ``[i, i + horizon)`` (``horizon=None`` = rest of the run) without a
        per-window ``decide`` call. Return None (the default) to force the
        engine onto the per-window host loop — correct for any scheduler,
        required for ones with per-window host state or side effects.

        Contract:
          * `indicator_fn` must be a module-level (stable-identity)
            function — it becomes a static argument of the engine's jitted
            scan, so a fresh closure per call would recompile every chunk;
            per-instance knobs must travel in `args` instead;
          * `args` is an arbitrary jnp-array pytree passed through to every
            ``indicator_fn(t, n_buf, args)`` call (traced, not static);
          * decisions must match `decide` exactly for the same windows —
            the two execution strategies are required to produce
            bit-identical trajectories (tests/test_protocol_lockstep.py);
          * the hook may do host work up front (e.g. FedSpace re-plans its
            schedule here, simulating the boundary window's upload so the
            search sees the same post-upload state `decide` would).

        `link` mirrors the `decide` kwarg (run-level LinkGate or None);
        the returned indicator itself needs no gating — the engine's scan
        applies the gate inside the shared upload/download transitions.

        Under *blind* fault injection (`repro.core.faults`) the
        `connectivity`/`link` the scheduler receives are the clean
        **plan view**, while the run executes on fault-masked artifacts;
        the engine then additionally passes `exec_connectivity` /
        `exec_link` keyword args (hence the `**_` tolerance here) so
        schedulers that simulate the boundary window's upload (FedSpace)
        can replicate what the engine actually executed. Under an oracle
        trace — or without faults — plan and exec views are the same
        objects.
        """
        return None


@register_scheduler("sync")
class SyncScheduler(Scheduler):
    """Wait for every satellite (FedAvg round over the full constellation)."""
    name = "sync"

    def decide(self, i, *, n_in_buffer, K, **_):
        return n_in_buffer >= K

    def device_plan(self, i, *, K, **_):
        return _sync_indicator, jnp.int32(K), None


@register_scheduler("async")
class AsyncScheduler(Scheduler):
    """Aggregate whenever anything is in the buffer."""
    name = "async"

    def decide(self, i, *, n_in_buffer, **_):
        return n_in_buffer > 0

    def device_plan(self, i, **_):
        return _async_indicator, jnp.int32(0), None


@register_scheduler("fedbuff")
class FedBuffScheduler(Scheduler):
    """Aggregate once the buffer reaches M (Nguyen et al. 2021)."""
    name = "fedbuff"

    def __init__(self, M: int = 96):
        self.M = M

    def decide(self, i, *, n_in_buffer, **_):
        return n_in_buffer >= self.M

    def device_plan(self, i, **_):
        return _fedbuff_indicator, jnp.int32(self.M), None


@register_scheduler("periodic")
class PeriodicScheduler(Scheduler):
    """Beyond-paper baseline: aggregate every P windows regardless of buffer
    content (a 'cron' server)."""
    name = "periodic"

    def __init__(self, period: int = 4):
        self.period = period

    def decide(self, i, *, n_in_buffer, **_):
        return n_in_buffer > 0 and (i + 1) % self.period == 0

    def device_plan(self, i, **_):
        return _periodic_indicator, jnp.int32(self.period), None


@register_scheduler("fedspace")
class FedSpaceScheduler(Scheduler):
    """The paper's scheduler: every I0 windows, random-search a schedule for
    the next I0 windows against the utility regressor û, using the known
    future connectivity and current protocol state (eq. 13)."""
    name = "fedspace"

    def __init__(self, regressor, *, I0: int = 24, n_min: int = None,
                 n_max: int = None, num_candidates: int = 5000,
                 s_max: int = 8, seed: int = 0, service=None):
        self.regressor = regressor
        self.I0 = I0
        self.n_min = n_min       # None => inferred from û (paper §3.2)
        self.n_max = n_max
        self.num_candidates = num_candidates
        self.s_max = s_max
        self.seed = seed
        # optional repro.fl.replan.ReplanService: when attached, every
        # re-plan routes through the service (persistent scan cache +
        # regressor handoff) instead of a fresh fedspace_search call.
        # Boundary-stride replans are full rescans either way, so routed
        # runs are bit-identical to unrouted ones — the delta path pays
        # off when the service is additionally driven per-window
        # (examples/serve_replan.py, the `replan` benchmark section).
        if service is not None and (service.I0 != I0
                                    or service.s_max != s_max
                                    or service.num_candidates
                                    != num_candidates):
            raise ValueError(
                "ReplanService knobs must match the scheduler: service "
                f"(I0={service.I0}, s_max={service.s_max}, "
                f"R={service.num_candidates}) vs scheduler (I0={I0}, "
                f"s_max={s_max}, R={num_candidates})")
        self.service = service
        self.reset()

    def reset(self):
        self._rng = np.random.default_rng(self.seed)
        self._schedule: Optional[np.ndarray] = None
        self._window_start = -1
        if self.service is not None:
            self.service.invalidate("reset")

    def _window_link(self, link, i):
        """Slice the run-level link gate to the planning window [i, i+I0),
        zero-padding the horizon tail like the connectivity slice."""
        if link is None:
            return None
        Gw = np.asarray(link.grant)[i:i + self.I0]
        if Gw.shape[0] < self.I0:
            Gw = np.concatenate(
                [Gw, np.zeros((self.I0 - Gw.shape[0], Gw.shape[1]),
                              Gw.dtype)], axis=0)
        return SS.LinkGate(Gw, link.need_up, link.need_dn)

    @staticmethod
    def _search_state(state, i, *, connectivity, link):
        """Invert window i's already-applied upload-grant accumulation.

        The search receives the *post-upload* state at window i (that is
        what `decide` sees) and its rollout re-simulates window i from the
        top, including the upload phase. Without link gating that re-run
        is idempotent — every connected pending update already left for
        the buffer, so the upload mask is empty. With gating it is not:
        a mid-upload satellite keeps `pending` and its `progress` already
        holds window i's grant, so the rollout would add the same grant a
        second time and predict every in-flight upload one grant ahead of
        what the engine will execute. Subtracting the grant from exactly
        the still-in-flight uploaders (connected & pending — completed
        uploads reset progress and drop pending, so they are excluded by
        construction) makes re-applying `upload_step` reproduce the
        engine's state bit-for-bit."""
        if link is None or state.progress is None:
            return state
        conn = jnp.asarray(np.asarray(connectivity[i], bool))
        grant = jnp.asarray(np.asarray(link.grant[i]),
                            state.progress.dtype)
        undo = jnp.where(conn & (state.pending >= 0), grant, 0)
        return state._replace(progress=state.progress - undo)

    def _ensure_schedule(self, i, *, state, ig, connectivity, status,
                         link=None):
        """(Re-)plan at I0 boundaries (eq. 13). `state` must be the
        post-upload state at window i — that is what `decide` receives from
        the engine, and what the search's simulator assumes. Under a link
        budget, `connectivity` is the effective matrix and the search's
        protocol rollouts are gated by the same per-window grants the
        engine will apply, so FedSpace schedules against transfers that can
        actually complete."""
        if self._schedule is not None and \
                (i % self.I0 != 0 or self._window_start == i):
            return
        Cw = connectivity[i:i + self.I0]
        if Cw.shape[0] < self.I0:   # pad the tail of the horizon
            pad = np.zeros((self.I0 - Cw.shape[0], Cw.shape[1]), bool)
            Cw = np.concatenate([Cw, pad], axis=0)
        n_min, n_max = self.n_min, self.n_max
        if n_min is None or n_max is None:
            from repro.core.search import infer_n_range
            inf_min, inf_max = infer_n_range(
                self.regressor, float(Cw.mean(axis=1).sum()) / self.I0
                * Cw.shape[1], self.I0, status, s_max=self.s_max,
                K=Cw.shape[1])
            n_min = n_min if n_min is not None else inf_min
            n_max = n_max if n_max is not None else inf_max
        search_state = self._search_state(state, i,
                                          connectivity=connectivity,
                                          link=link)
        if self.service is not None:
            # route through the replan service: same draw (the scheduler's
            # rng), same scorer, same selection — bit-identical schedules —
            # but the service keeps the scan cache and the regressor across
            # requests (docs/replanning.md)
            self.service.mesh = self.mesh
            self._schedule = self.service.replan(
                i, Cw, search_state, ig, status,
                link=self._window_link(link, i), rng=self._rng,
                n_min=n_min, n_max=n_max)
        else:
            self._schedule = fedspace_search(
                self._rng, Cw, search_state, ig, self.regressor, status,
                n_min=n_min, n_max=n_max,
                num_candidates=self.num_candidates, s_max=self.s_max,
                link=self._window_link(link, i), mesh=self.mesh)
        self._window_start = i

    def decide(self, i, *, n_in_buffer, K, state, ig, connectivity, status,
               link=None, **_):
        self._ensure_schedule(i, state=state, ig=ig,
                              connectivity=connectivity, status=status,
                              link=link)
        a = bool(self._schedule[i - self._window_start])
        return a and n_in_buffer > 0

    def device_plan(self, i, *, K, state, ig, connectivity, status,
                    link=None, exec_connectivity=None, exec_link=None, **_):
        if i % self.I0 == 0 or self._schedule is None:
            # `decide` runs after the engine's upload step; replicate that
            # here so the search scores the identical post-upload state
            # (the scan recomputes this upload — one extra dispatch per
            # re-plan, amortized over I0 windows). Under blind fault
            # injection the engine's upload runs on the *executed*
            # fault-masked world (exec_connectivity/exec_link), so the
            # boundary simulation must too — that hands `_ensure_schedule`
            # the same post-upload state the host loop's `decide` sees —
            # while the search itself keeps planning on the clean view.
            bc = connectivity if exec_connectivity is None \
                else exec_connectivity
            bl = link if exec_connectivity is None else exec_link
            conn = jnp.asarray(np.asarray(bc[i], bool))
            gate = None if bl is None else SS.LinkGate(
                jnp.asarray(np.asarray(bl.grant[i]), jnp.int32),
                jnp.int32(bl.need_up), jnp.int32(bl.need_dn))
            state, _ = SS.upload_step(state, jnp.int32(ig), conn, gate)
            self._ensure_schedule(i, state=state, ig=ig,
                                  connectivity=connectivity, status=status,
                                  link=link)
        args = (jnp.asarray(self._schedule, jnp.int32),
                jnp.int32(self._window_start))
        return _fedspace_indicator, args, \
            self._window_start + self.I0 - i


@register_scheduler("intra_plane")
class IntraPlaneScheduler(Scheduler):
    """Sink-satellite scheduling over intra-plane ISLs (arXiv 2302.13447):
    every plane relays its members' updates along the ring to an elected
    sink, which uplinks them in one ground pass; the GS aggregates once
    every *reachable* satellite's update has arrived.

    `M` overrides the aggregation threshold; the default (None) resolves
    it to the number of satellites in planes with at least one effective
    ground contact over the run (`repro.core.isl.reachable_count`) — a
    sync barrier over the satellites that can contribute at all, which is
    what keeps the policy live when part of the constellation (e.g.
    mid-inclination Starlink shells over a polar-only ground network)
    never sees a station. Election cadence and hop latency live in the
    run's `ISLConfig`; without an ISL runtime the scheduler degrades to a
    plain sync-over-K barrier on physical contacts."""
    name = "intra_plane"
    isl_mode = "sink"

    def __init__(self, M: Optional[int] = None):
        self.M = M
        self.reset()

    def reset(self):
        self._M_resolved: Optional[int] = None

    def _threshold(self, connectivity, K) -> int:
        if self.M is not None:
            return self.M
        if self._M_resolved is None:
            if self.isl is None:
                self._M_resolved = K
            else:
                from repro.core.isl import reachable_count
                self._M_resolved = max(
                    reachable_count(self.isl.topology, connectivity), 1)
        return self._M_resolved

    def decide(self, i, *, n_in_buffer, K, connectivity, **_):
        return n_in_buffer >= self._threshold(connectivity, K)

    def device_plan(self, i, *, K, connectivity, **_):
        return _fedbuff_indicator, \
            jnp.int32(self._threshold(connectivity, K)), None


@register_scheduler("isl_async")
class IslAsyncScheduler(Scheduler):
    """Asynchronous FL over intra-plane gossip (arXiv 2206.00307): ring
    neighbours exchange models between ground contacts (the engine's
    gossip transition), satellites upload at their own physical contacts,
    and the GS aggregates as soon as `M` updates are buffered (default 1
    — fully asynchronous, eq. 6, which is the regime the cited paper
    targets). The gossip hop period comes from the run's `ISLConfig`
    rate/model-size sentinels."""
    name = "isl_async"
    isl_mode = "gossip"

    def __init__(self, M: int = 1):
        self.M = max(int(M), 1)

    def decide(self, i, *, n_in_buffer, **_):
        return n_in_buffer >= self.M

    def device_plan(self, i, **_):
        return _fedbuff_indicator, jnp.int32(self.M), None


def make_scheduler(name: str, **kw) -> Scheduler:
    """Build a registered scheduler by name. Unknown names raise a KeyError
    listing what is registered (see repro.fl.registry)."""
    return SCHEDULERS.build(name, **kw)
