"""Staleness / idleness dynamics (paper eqs. 4, 9, 10) and the vectorized
window simulator that scores candidate aggregation schedules.

Protocol semantics (Algorithm 1 + Appendix A):
  at each time index i, for every connected satellite k in C_i:
    1. upload: if k holds a trained update (base version b_k), it enters the
       GS buffer with staleness s_k = i_g - b_k *at aggregation time*;
    2. if a^i = 1 the GS aggregates the buffer and increments i_g;
    3. download: k receives the current global model; if its version is newer
       than what k last received, k starts a new local round from it.
  A connection is *idle* when the satellite has nothing to upload (no
  aggregation happened between its two previous contacts — eq. 10).

`simulate_window` is pure JAX and vmappable over candidate schedules — it is
the inner loop of the FedSpace random search (eq. 13).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def staleness_compensation(s, alpha: float = 0.5):
    """c_alpha(s) = (s+1)^(-alpha) (paper §2.3, after Xie et al. 2019)."""
    return (s.astype(jnp.float32) + 1.0) ** (-alpha) \
        if hasattr(s, "astype") else (s + 1.0) ** (-alpha)


class SatState(NamedTuple):
    """Per-satellite protocol state. Arrays of shape (..., K)."""
    version: jnp.ndarray     # last global version received (-1 = never)
    pending: jnp.ndarray     # base version of trained-but-unsent update (-1)
    buffered: jnp.ndarray    # base version of update sitting in GS buffer (-1)


def init_state(K: int) -> SatState:
    m1 = jnp.full((K,), -1, jnp.int32)
    return SatState(version=m1, pending=m1, buffered=m1)


def bootstrap_state(K: int) -> SatState:
    """All satellites already hold version 0 and have a pending update on it
    (the GS seeds the constellation with w^0)."""
    return SatState(version=jnp.zeros((K,), jnp.int32),
                    pending=jnp.zeros((K,), jnp.int32),
                    buffered=jnp.full((K,), -1, jnp.int32))


# ---------------------------------------------------------------------------
# Algorithm-1 sub-transitions. These three pure functions are THE protocol:
# the schedule-search simulator scans their composition (`step`), and the FL
# engine (repro.fl.engine) drives the same functions one event at a time, so
# both layers share one transition semantics by construction.


def upload_step(state: SatState, ig, connected):
    """Phase 1 of a time index: connected satellites hand their pending
    update to the GS buffer; idle contacts (eq. 10) are counted.

    Returns (new_state, info) with masks/counters on device:
      uploads (K,) bool, idle (K,) bool,
      n_connected, n_idle, n_buffered — scalar int32.
    """
    has_pending = state.pending >= 0
    uploads = connected & has_pending
    buffered = jnp.where(uploads, state.pending, state.buffered)
    pending = jnp.where(uploads, -1, state.pending)

    # idle: connected, nothing to send, nothing new to fetch (eq. 10)
    idle = connected & (~has_pending) & (state.version == ig)
    info = {"uploads": uploads, "idle": idle,
            "n_connected": jnp.sum(connected.astype(jnp.int32)),
            "n_idle": jnp.sum(idle.astype(jnp.int32)),
            "n_buffered": jnp.sum((buffered >= 0).astype(jnp.int32))}
    return SatState(state.version, pending, buffered), info


def aggregate_step(state: SatState, ig, aggregate, *, s_max: int):
    """Phase 2: when a^i = 1 and the buffer is non-empty, consume the buffer
    and advance the global version (a no-op on an empty buffer — eq. 4 has
    nothing to sum; the global version must not advance spuriously).

    Returns (new_state, new_ig, info) with:
      hist (s_max+1,), n_aggregated, max_staleness, aggregated (K,) bool.
    """
    in_buffer = state.buffered >= 0
    aggregate = jnp.logical_and(aggregate, jnp.any(in_buffer))
    stale = jnp.where(in_buffer, ig - state.buffered, 0)
    stale_c = jnp.clip(stale, 0, s_max)
    counted = in_buffer & aggregate
    # histogram as compare+reduce rather than scatter-add: identical
    # integer counts, but ~4x faster on CPU inside the vmapped search scan
    # (XLA lowers the (R, K)->(R, s_max+1) scatter poorly there)
    hist = jnp.sum((stale_c[..., None] == jnp.arange(s_max + 1))
                   & counted[..., None], axis=-2, dtype=jnp.int32)
    n_agg = jnp.sum(counted.astype(jnp.int32))
    max_stale = jnp.max(jnp.where(counted, stale, 0))
    new_ig = ig + aggregate.astype(jnp.int32)
    buffered = jnp.where(aggregate, -1, state.buffered)
    info = {"hist": hist, "n_aggregated": n_agg,
            "max_staleness": max_stale, "aggregated": counted}
    return SatState(state.version, state.pending, buffered), new_ig, info


def download_step(state: SatState, ig, connected):
    """Phase 3: connected satellites fetch the current global model and, if
    it is newer than what they last received, start a fresh local round.

    Returns (new_state, info) with the download mask on device.
    """
    gets_new = connected & (state.version < ig)
    version = jnp.where(gets_new, ig, state.version)
    pending = jnp.where(gets_new, ig, state.pending)
    return SatState(version, pending, state.buffered), \
        {"downloads": gets_new}


def step(state: SatState, ig, connected, aggregate, *, s_max: int):
    """One time index of the protocol: upload ∘ aggregate ∘ download.

    Args:
      state: SatState (K,)
      ig: scalar int32 global round index
      connected: (K,) bool — C_i
      aggregate: scalar bool — a^i
      s_max: staleness histogram clip

    Returns: (new_state, new_ig, info) where info has:
      hist: (s_max+1,) counts of aggregated gradients per clipped staleness
      n_aggregated, n_idle, max_staleness (only meaningful when aggregate)
    """
    state, up = upload_step(state, ig, connected)
    state, new_ig, agg = aggregate_step(state, ig, aggregate, s_max=s_max)
    state, _ = download_step(state, new_ig, connected)
    info = {"hist": agg["hist"], "n_aggregated": agg["n_aggregated"],
            "n_idle": up["n_idle"], "max_staleness": agg["max_staleness"]}
    return state, new_ig, info


def simulate_window(C_window, a, state: SatState, ig, *, s_max: int = 8,
                    lite: bool = False):
    """Roll the protocol over a scheduling window.

    Args:
      C_window: (I0, K) bool future connectivity (deterministic!)
      a: (I0,) {0,1} candidate aggregation schedule
      state, ig: protocol state at window start
      lite: emit only the staleness histograms — the scalar diagnostics
        (n_idle, n_aggregated, max_staleness) become dead outputs and XLA
        eliminates their per-step reductions, which is measurably faster
        inside the vmapped search at R = thousands of candidates

    Returns (final_state, final_ig, infos) with infos stacked over I0:
      hist (I0, s_max+1) and, unless lite, n_aggregated (I0,), ...
    """
    def body(carry, inp):
        st, g = carry
        c, ai = inp
        st, g, info = step(st, g, c, ai.astype(bool), s_max=s_max)
        return (st, g), ({"hist": info["hist"]} if lite else info)

    (state, ig), infos = jax.lax.scan(
        body, (state, ig), (C_window, a.astype(jnp.int32)))
    return state, ig, infos


# vmap over candidate schedules: a (R, I0) -> infos stacked over R.
def simulate_candidates(C_window, candidates, state: SatState, ig, *,
                        s_max: int = 8, lite: bool = False):
    """`simulate_window` vmapped over candidate schedules (axis 0)."""
    return jax.vmap(lambda a: simulate_window(C_window, a, state, ig,
                                              s_max=s_max, lite=lite)
                    )(candidates)


# ---------------------------------------------------------------------------
# Baseline aggregation indicators (paper §2.4) as predicates over GS state.


def sync_indicator(n_in_buffer: int, K: int, **_) -> bool:
    """a_sync = 1{R_i = K} (eq. 5)."""
    return n_in_buffer >= K


def async_indicator(n_in_buffer: int, **_) -> bool:
    """a_async = 1{R_i != empty} (eq. 6)."""
    return n_in_buffer > 0


def fedbuff_indicator(n_in_buffer: int, M: int, **_) -> bool:
    """a_fedbuff = 1{|R_i| >= M} (eq. 7)."""
    return n_in_buffer >= M
