"""Staleness / idleness dynamics (paper eqs. 4, 9, 10) and the vectorized
window simulator that scores candidate aggregation schedules.

Protocol semantics (Algorithm 1 + Appendix A):
  at each time index i, for every connected satellite k in C_i:
    1. upload: if k holds a trained update (base version b_k), it enters the
       GS buffer with staleness s_k = i_g - b_k *at aggregation time*;
    2. if a^i = 1 the GS aggregates the buffer and increments i_g;
    3. download: k receives the current global model; if its version is newer
       than what k last received, k starts a new local round from it.
  A connection is *idle* when the satellite has nothing to upload (no
  aggregation happened between its two previous contacts — eq. 10).

`simulate_window` is pure JAX and vmappable over candidate schedules — it is
the inner loop of the FedSpace random search (eq. 13).

Fault injection (`repro.core.faults`) composes with these transitions from
the outside: the engine masks the connectivity/grant artifacts they consume
and applies `repro.core.faults.fault_reset` (re-entry of recovered
satellites as "never received") between windows, so no transition here
needs a fault branch and fault-free runs compile the exact same programs.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


def staleness_compensation(s, alpha: float = 0.5):
    """c_alpha(s) = (s+1)^(-alpha) (paper §2.3, after Xie et al. 2019)."""
    return (s.astype(jnp.float32) + 1.0) ** (-alpha) \
        if hasattr(s, "astype") else (s + 1.0) ** (-alpha)


def _psum(x, axis_name):
    """Cross-device sum when the satellite axis is sharded (`axis_name`
    names the mesh axis, see `repro.core.mesh`), identity otherwise. All
    protocol reductions are integer, so the cross-shard reassociation is
    exact — the `axis_name=None` path compiles literally the same program
    as every previous release."""
    return x if axis_name is None else jax.lax.psum(x, axis_name)


class SatState(NamedTuple):
    """Per-satellite protocol state. Arrays of shape (..., K).

    `progress` is the in-progress-transfer column of the link-budget layer:
    contact units accumulated toward the satellite's current transfer (the
    pending upload while one exists, the model download otherwise). It is
    ``None`` — an empty pytree node, invisible to jit/scan/vmap — unless the
    run models finite link budgets (see `LinkGate`), so geometry-only
    callers keep the exact three-column state of previous releases.

    `relay` is the intra-plane relay column of the ISL layer
    (`repro.core.isl`): hop units the satellite's pending update has
    accumulated toward its plane's sink satellite. Same empty-pytree-node
    idiom — ``None`` unless the run models sink-satellite relaying, so
    non-ISL callers are untouched bit-for-bit."""
    version: jnp.ndarray     # last global version received (-1 = never)
    pending: jnp.ndarray     # base version of trained-but-unsent update (-1)
    buffered: jnp.ndarray    # base version of update sitting in GS buffer (-1)
    progress: Optional[jnp.ndarray] = None  # in-progress transfer units
    relay: Optional[jnp.ndarray] = None     # accumulated ISL hop units


class LinkGate(NamedTuple):
    """Link-budget gating for `upload_step` / `download_step`.

    `grant` holds the contact units (visible propagation substeps at the
    contention-assigned ground station — see
    `repro.core.connectivity.link_budget`) each satellite is granted:
    shape (K,) for a single transition, (I0, K) scanned along the window
    axis inside `simulate_window`, or the full (num_windows, K) matrix when
    the engine hands a run-level budget to a scheduler. `need_up` /
    `need_dn` are the units required to complete an upload / download
    (scalars; 0 = instantaneous, which reproduces the geometry-only
    protocol bit-identically). A transfer completes only in a window where
    the accumulated `SatState.progress` plus this window's grant reaches
    the threshold; progress persists across non-contact windows, so
    transfers span multiple contact windows when grants are short.

    Accounting is full-duplex at window granularity: the uplink and
    downlink are separate channels sharing the same contact time, so a
    window whose grant completes an upload contributes its full grant to
    the download that starts in the same window (that is also what makes
    zero needs reproduce the instantaneous both-directions-per-contact
    geometry semantics bit-for-bit); surplus upload units beyond
    `need_up` are otherwise discarded, not carried over."""
    grant: jnp.ndarray
    need_up: jnp.ndarray
    need_dn: jnp.ndarray


def init_state(K: int, *, progress: bool = False,
               relay: bool = False) -> SatState:
    m1 = jnp.full((K,), -1, jnp.int32)
    return SatState(version=m1, pending=m1, buffered=m1,
                    progress=jnp.zeros((K,), jnp.int32) if progress
                    else None,
                    relay=jnp.zeros((K,), jnp.int32) if relay else None)


def bootstrap_state(K: int, *, progress: bool = False,
                    relay: bool = False) -> SatState:
    """All satellites already hold version 0 and have a pending update on it
    (the GS seeds the constellation with w^0). `progress=True` attaches the
    zeroed in-progress-transfer column for link-budget runs; `relay=True`
    the zeroed ISL relay column for sink-satellite runs."""
    return SatState(version=jnp.zeros((K,), jnp.int32),
                    pending=jnp.zeros((K,), jnp.int32),
                    buffered=jnp.full((K,), -1, jnp.int32),
                    progress=jnp.zeros((K,), jnp.int32) if progress
                    else None,
                    relay=jnp.zeros((K,), jnp.int32) if relay else None)


# ---------------------------------------------------------------------------
# Algorithm-1 sub-transitions. These three pure functions are THE protocol:
# the schedule-search simulator scans their composition (`step`), and the FL
# engine (repro.fl.engine) drives the same functions one event at a time, so
# both layers share one transition semantics by construction.


def upload_step(state: SatState, ig, connected, link: Optional[LinkGate]
                = None, *, axis_name: Optional[str] = None):
    """Phase 1 of a time index: connected satellites hand their pending
    update to the GS buffer; idle contacts (eq. 10) are counted.

    Pure masked `jnp.where` updates over the dense (..., K) state — no
    gathers/scatters — and dtype-preserving, so int16-narrowed search
    states stay narrow through the vmapped scan.

    `link` (a per-window `LinkGate`, grant shape (..., K)) activates
    transfer gating: a connected satellite with a pending update
    accumulates this window's grant into `SatState.progress` and the
    upload enters the buffer only once progress reaches `need_up`
    (progress then resets for the next transfer). `link=None` — or a gate
    with `need_up == 0` — reproduces the instantaneous-upload semantics
    bit-for-bit. `connected` is the *effective* (capacity-resolved)
    connectivity when link budgets are modeled, so the idle/connected
    counters then count served contacts.

    `axis_name` marks the satellite axis as sharded across a device mesh:
    the masks stay per-shard but the three counters become cross-device
    `psum`s (exact — integer sums) so every shard sees the global values.

    Returns (new_state, info) with masks/counters on device:
      uploads (K,) bool, idle (K,) bool,
      n_connected, n_idle, n_buffered — scalar int32.
    """
    has_pending = state.pending >= 0
    active = connected & has_pending
    if link is None:
        uploads = active
        progress = state.progress
    else:
        progress = state.progress + jnp.where(active, link.grant, 0)
        uploads = active & (progress >= link.need_up)
        progress = jnp.where(uploads, 0, progress)
    buffered = jnp.where(uploads, state.pending, state.buffered)
    pending = jnp.where(uploads, _m1(state.pending), state.pending)

    # idle: connected, nothing to send, nothing new to fetch (eq. 10)
    idle = connected & (~has_pending) & (state.version == ig)
    info = {"uploads": uploads, "idle": idle,
            "n_connected": _psum(jnp.sum(connected.astype(jnp.int32)),
                                 axis_name),
            "n_idle": _psum(jnp.sum(idle.astype(jnp.int32)), axis_name),
            "n_buffered": _psum(jnp.sum((buffered >= 0).astype(jnp.int32)),
                                axis_name)}
    return SatState(state.version, pending, buffered, progress,
                    state.relay), info


def aggregate_step(state: SatState, ig, aggregate, *, s_max: int,
                   collect: str = "hist",
                   axis_name: Optional[str] = None):
    """Phase 2: when a^i = 1 and the buffer is non-empty, consume the buffer
    and advance the global version (a no-op on an empty buffer — eq. 4 has
    nothing to sum; the global version must not advance spuriously).

    Args:
      state: SatState (..., K); any signed-int dtype (the transition is
        dtype-preserving, so narrow-state callers stay narrow).
      ig: scalar global round index, same dtype as the state arrays.
      aggregate: scalar bool — the schedule indicator a^i.
      s_max: staleness histogram / marks clip.
      collect: which diagnostics to emit alongside the state transition —
        * ``"hist"`` (default): the full PR-3 info dict
          {hist (s_max+1,), n_aggregated, max_staleness, aggregated (K,)};
          bit-identical to every previous release.
        * ``"marks"``: {marks (K,)} — each aggregated satellite's clipped
          staleness, -1 for satellites not aggregated this index (int8 when
          s_max <= 126 so vmapped scans stream R*K bytes per step, not
          R*(s_max+1) histogram broadcasts; see `hist_from_marks`).
        * ``"none"``: {} — state transition only (the per-step reductions
          disappear from the compiled program even without relying on DCE).
      axis_name: satellite axis sharded across a device mesh — the
        empty-buffer guard and the histogram/count diagnostics become
        cross-device reductions (exact integer psums; max via pmax) so
        every shard takes the same aggregate-or-not branch.

    Returns (new_state, new_ig, info).
    """
    in_buffer = state.buffered >= 0
    any_buf = jnp.any(in_buffer)
    if axis_name is not None:
        any_buf = _psum(any_buf.astype(jnp.int32), axis_name) > 0
    aggregate = jnp.logical_and(aggregate, any_buf)
    new_ig = ig + aggregate.astype(jnp.asarray(ig).dtype)
    buffered = jnp.where(aggregate, _m1(state.buffered), state.buffered)
    new_state = SatState(state.version, state.pending, buffered,
                         state.progress, state.relay)
    if collect == "none":
        return new_state, new_ig, {}
    counted = in_buffer & aggregate
    if collect == "marks":
        stale_c = jnp.clip(ig - state.buffered, 0, s_max)
        marks = jnp.where(counted, stale_c, -1).astype(marks_dtype(s_max))
        return new_state, new_ig, {"marks": marks}
    stale = jnp.where(in_buffer, ig - state.buffered, 0)
    stale_c = jnp.clip(stale, 0, s_max)
    # histogram as compare+reduce rather than scatter-add: identical
    # integer counts, but ~4x faster on CPU inside the vmapped search scan
    # (XLA lowers the (R, K)->(R, s_max+1) scatter poorly there)
    hist = _psum(jnp.sum((stale_c[..., None] == jnp.arange(s_max + 1))
                         & counted[..., None], axis=-2, dtype=jnp.int32),
                 axis_name)
    n_agg = _psum(jnp.sum(counted.astype(jnp.int32)), axis_name)
    max_stale = jnp.max(jnp.where(counted, stale, 0))
    if axis_name is not None:
        max_stale = jax.lax.pmax(max_stale, axis_name)
    info = {"hist": hist, "n_aggregated": n_agg,
            "max_staleness": max_stale, "aggregated": counted}
    return new_state, new_ig, info


def marks_dtype(s_max: int):
    """Narrowest dtype that can hold clipped staleness marks (-1..s_max)."""
    return jnp.int8 if s_max <= 126 else jnp.int32


def _m1(ref):
    """-1 in `ref`'s dtype (keeps narrow-state transitions narrow — a bare
    Python -1 would stay weakly typed and is fine, but being explicit keeps
    the promotion rules out of the parity story)."""
    return jnp.asarray(-1, jnp.asarray(ref).dtype)


def hist_from_marks(marks, *, s_max: int, dtype=jnp.int32):
    """Staleness histograms from aggregation `marks`, batched over any
    leading axes: (..., K) -> (..., s_max+1).

    `marks` holds each aggregated satellite's clipped staleness and -1
    everywhere else (the ``collect="marks"`` output of `aggregate_step` /
    `step`), so counting value matches recovers exactly the integer counts
    the in-step ``"hist"`` path emits. The count is a two-level blocked
    reduction over the contiguous K axis — int8 partial sums over blocks
    of 8 (a block count can never exceed 8, so the narrow accumulator is
    exact), then `dtype` across blocks — which SIMD-vectorizes more than
    an order of magnitude better on CPU than a single widening reduce.
    """
    s = jnp.arange(s_max + 1, dtype=marks.dtype)
    pad = -marks.shape[-1] % 8
    if pad:   # -2 matches no staleness value, so padding never counts
        marks = jnp.concatenate(
            [marks, jnp.full(marks.shape[:-1] + (pad,), -2, marks.dtype)],
            axis=-1)
    blocks = marks[..., None, :].reshape(
        marks.shape[:-1] + (1, marks.shape[-1] // 8, 8))
    part = jnp.sum(blocks == s[:, None, None], axis=-1, dtype=jnp.int8)
    return jnp.sum(part, axis=-1, dtype=dtype)


def download_step(state: SatState, ig, connected, link: Optional[LinkGate]
                  = None):
    """Phase 3: connected satellites fetch the current global model and, if
    it is newer than what they last received, start a fresh local round.

    Masked `jnp.where` updates only, dtype-preserving (pass `ig` in the
    state's dtype to keep narrowed states narrow).

    `link` activates transfer gating: a behind-version satellite with no
    un-uploaded pending update (the uplink drains first — satellites finish
    pushing the trained round before pulling the new model, which is also
    what makes one `progress` column sufficient) accumulates this window's
    grant and receives the model only once progress reaches `need_dn`.
    Downloads always deliver the *current* global version: an in-flight
    download re-targets the newest model if `ig` advances mid-transfer,
    without resetting progress. `link=None` or `need_dn == 0` is the
    instantaneous path, bit-for-bit.

    Returns (new_state, info) with the download mask on device.
    """
    gets_new = connected & (state.version < ig)
    if link is None:
        done = gets_new
        progress = state.progress
    else:
        active = gets_new & (state.pending < 0)
        progress = state.progress + jnp.where(active, link.grant, 0)
        done = active & (progress >= link.need_dn)
        progress = jnp.where(done, 0, progress)
    version = jnp.where(done, ig, state.version)
    pending = jnp.where(done, ig, state.pending)
    return SatState(version, pending, state.buffered, progress,
                    state.relay), {"downloads": done}


def step(state: SatState, ig, connected, aggregate, *, s_max: int,
         collect: str = "hist", link: Optional[LinkGate] = None,
         axis_name: Optional[str] = None):
    """One time index of the protocol: upload ∘ aggregate ∘ download.

    Args:
      state: SatState (K,); any signed-int dtype (dtype-preserving).
      ig: scalar global round index (same dtype as the state arrays)
      connected: (K,) bool — C_i (the capacity-resolved effective
        connectivity when link budgets are modeled)
      aggregate: scalar bool — a^i
      s_max: staleness histogram clip
      collect: diagnostics to emit — ``"hist"`` (default, the full PR-3
        info dict), ``"marks"`` (compact per-satellite staleness marks; see
        `aggregate_step`), or ``"none"``.
      link: optional per-window `LinkGate` (grant (K,)) gating uploads and
        downloads on accumulated transfer progress; None = instantaneous
        transfers (bit-identical to every previous release).
      axis_name: satellite axis sharded across a device mesh — threaded to
        the sub-transitions so counters/histograms and the empty-buffer
        guard reduce across shards (see `repro.core.mesh`).

    Returns: (new_state, new_ig, info) where info (collect="hist") has:
      hist: (s_max+1,) counts of aggregated gradients per clipped staleness
      n_aggregated, n_idle, max_staleness (only meaningful when aggregate)
    """
    state, up = upload_step(state, ig, connected, link,
                            axis_name=axis_name)
    state, new_ig, agg = aggregate_step(state, ig, aggregate, s_max=s_max,
                                        collect=collect,
                                        axis_name=axis_name)
    state, _ = download_step(state, new_ig, connected, link)
    if collect != "hist":
        return state, new_ig, agg
    info = {"hist": agg["hist"], "n_aggregated": agg["n_aggregated"],
            "n_idle": up["n_idle"], "max_staleness": agg["max_staleness"]}
    return state, new_ig, info


def simulate_window(C_window, a, state: SatState, ig, *, s_max: int = 8,
                    lite: bool = False, collect: Optional[str] = None,
                    link: Optional[LinkGate] = None,
                    axis_name: Optional[str] = None):
    """Roll the protocol over a scheduling window.

    Args:
      C_window: (I0, K) bool future connectivity (deterministic!) — the
        effective, capacity-resolved matrix when link budgets are modeled
      a: (I0,) {0,1} candidate aggregation schedule
      state, ig: protocol state at window start (`state.progress` must be
        attached when `link` is given)
      lite: emit only the staleness histograms — the scalar diagnostics
        (n_idle, n_aggregated, max_staleness) become dead outputs and XLA
        eliminates their per-step reductions, which is measurably faster
        inside the vmapped search at R = thousands of candidates
      collect: overrides `lite` when given — ``"hist"`` (= lite=False),
        ``"marks"`` (infos carry only marks (I0, K): the scatter-free
        search path, recovered into histograms by `hist_from_marks`), or
        ``"none"`` (state/ig only, infos empty).
      link: optional `LinkGate` whose grant is (I0, K) — row i gates the
        transfers of window i; scanned alongside C_window.
      axis_name: satellite axis sharded across a device mesh — threaded to
        `step` so the scan runs embarrassingly parallel over K with only
        the counter/histogram psums crossing shards.

    Returns (final_state, final_ig, infos) with infos stacked over I0:
      hist (I0, s_max+1) and, unless lite, n_aggregated (I0,), ... — or
      marks (I0, K) under collect="marks".
    """
    if collect is None:
        collect = "hist"
        emit = (lambda info: {"hist": info["hist"]}) if lite \
            else (lambda info: info)
    else:
        emit = lambda info: info

    grants = () if link is None else (link.grant,)

    def body(carry, inp):
        st, g = carry
        c, ai = inp[0], inp[1]
        gate = None if link is None \
            else LinkGate(inp[2], link.need_up, link.need_dn)
        st, g, info = step(st, g, c, ai.astype(bool), s_max=s_max,
                           collect=collect, link=gate,
                           axis_name=axis_name)
        return (st, g), emit(info)

    (state, ig), infos = jax.lax.scan(
        body, (state, ig), (C_window, a.astype(jnp.int32)) + grants)
    return state, ig, infos


# vmap over candidate schedules: a (R, I0) -> infos stacked over R.
def simulate_candidates(C_window, candidates, state: SatState, ig, *,
                        s_max: int = 8, lite: bool = False,
                        collect: Optional[str] = None,
                        link: Optional[LinkGate] = None,
                        axis_name: Optional[str] = None):
    """`simulate_window` vmapped over candidate schedules (axis 0). The
    link gate (when given) is shared by every candidate — schedules differ
    in *when* they aggregate, not in the physics of the links."""
    return jax.vmap(lambda a: simulate_window(C_window, a, state, ig,
                                              s_max=s_max, lite=lite,
                                              collect=collect, link=link,
                                              axis_name=axis_name)
                    )(candidates)


# ---------------------------------------------------------------------------
# Baseline aggregation indicators (paper §2.4) as predicates over GS state.


def sync_indicator(n_in_buffer: int, K: int, **_) -> bool:
    """a_sync = 1{R_i = K} (eq. 5)."""
    return n_in_buffer >= K


def async_indicator(n_in_buffer: int, **_) -> bool:
    """a_async = 1{R_i != empty} (eq. 6)."""
    return n_in_buffer > 0


def fedbuff_indicator(n_in_buffer: int, M: int, **_) -> bool:
    """a_fedbuff = 1{|R_i| >= M} (eq. 7)."""
    return n_in_buffer >= M
