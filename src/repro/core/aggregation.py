"""Staleness-compensated buffered aggregation (paper eq. 4):

    w^{i+1} = w^i + sum_k  c(s_k)/C * g_k,    C = sum_k c(s_k)

Operates on pytrees of flat per-satellite update stacks. The hot spot — the
weighted reduction over the update buffer at full model size — is a Pallas
TPU kernel (repro.kernels.agg); this module falls back to the pure-jnp
reference away from TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.staleness import staleness_compensation


def aggregation_weights(staleness, alpha: float = 0.5):
    """Normalized c(s_k)/C weights. staleness: (M,) int array."""
    c = staleness_compensation(jnp.asarray(staleness), alpha)
    return c / jnp.maximum(jnp.sum(c), 1e-12)


def apply_aggregation(global_params, update_stack, staleness, *,
                      alpha: float = 0.5, server_lr: float = 1.0,
                      use_kernel: bool = False):
    """global_params: pytree; update_stack: pytree with leading buffer dim M
    (stacked g_k); staleness: (M,) int32.

    Returns updated params.
    """
    w = aggregation_weights(staleness, alpha) * server_lr

    if use_kernel:
        from repro.kernels.agg.ops import weighted_aggregate_tree
        delta = weighted_aggregate_tree(update_stack, w)
    else:
        delta = jax.tree.map(
            lambda u: jnp.tensordot(w.astype(jnp.float32),
                                    u.astype(jnp.float32), axes=1),
            update_stack)
    return jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
        global_params, delta)
