"""Staleness-compensated buffered aggregation (paper eq. 4):

    w^{i+1} = w^i + sum_k  c(s_k)/C * g_k,    C = sum_k c(s_k)

Operates on pytrees of per-satellite update stacks. The hot spot — the
weighted reduction over the update buffer at full model size — routes
through `repro.kernels.agg.ops.aggregate_params_tree`: the Pallas TPU
kernel on TPU, the bit-identical pure-jnp reduction elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.staleness import staleness_compensation
from repro.kernels.agg.ops import aggregate_params_tree


def aggregation_weights(staleness, alpha: float = 0.5):
    """Normalized c(s_k)/C weights. staleness: (M,) int array."""
    c = staleness_compensation(jnp.asarray(staleness), alpha)
    return c / jnp.maximum(jnp.sum(c), 1e-12)


def apply_aggregation(global_params, update_stack, staleness, *,
                      alpha: float = 0.5, server_lr: float = 1.0,
                      interpret=None):
    """global_params: pytree; update_stack: pytree with leading buffer dim M
    (stacked g_k); staleness: (M,) int32.

    Returns updated params. `interpret` forwards to the kernel dispatch
    (None = kernel on TPU, jnp reduction elsewhere).
    """
    w = aggregation_weights(staleness, alpha) * server_lr
    return aggregate_params_tree(global_params, update_stack, w,
                                 interpret=interpret)
