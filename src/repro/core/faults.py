"""Fault injection across the protocol stack: satellite churn, ground-station
outages, and weather-degraded links.

FedSpace's planning premise is that connectivity is *deterministic* (§3.1) —
but production constellations lose satellites mid-run, stations go dark for
maintenance, and weather scales link rates, so the planned schedule and the
executed contacts diverge. Matthiesen et al. (arXiv 2206.00307) motivate
asynchronous operation with exactly this unreliability, and the
sink/aggregator schemes (arXiv 2302.13447, 2401.15541) assume relay
satellites that can themselves fail. This module is that robustness layer:

  * `FaultConfig` — a seeded, declarative failure model: satellite
    deorbit/launch epochs, per-station outage windows, and a blockwise
    seeded link-rate multiplier (weather draws).
  * `fault_trace` — resolves a config into a deterministic per-window
    `FaultTrace`: a satellite-alive mask, a station-up mask, and a rate
    multiplier (plus, when per-station contact counts are supplied, the
    "reaches some up station" mask that folds outages into station-collapsed
    connectivity).
  * pure transforms over the existing artifacts — `mask_connectivity`
    masks a geometry matrix `C`, `mask_budget`/`mask_served` mask a
    `repro.core.connectivity.LinkBudget`'s visible/served/grants (grants are
    additionally rescaled by the weather multiplier) — and `fault_reset`,
    the protocol transition that re-admits recovered/launched satellites
    with a forced re-download (version/pending reset to "never received"),
    so they never train on a pre-outage model.

The engine (`repro.fl.engine.SimulationEngine(faults=...)`) executes on the
fault-masked artifacts under both execution strategies, while schedulers
plan on either the clean view (*blind*, the realistic default — the plan is
wrong and the run measures how gracefully each policy degrades) or the
faulted view (*oracle*, `FaultConfig(oracle=True)`). ``faults=None``
follows the `progress`/`relay` empty-pytree-node idiom: nothing of this
module enters the compiled programs and every trajectory is bit-identical
to previous releases (lockstep tests + the `faults` benchmark parity gate).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import staleness as SS
from repro.core.connectivity import LinkBudget


@dataclass(frozen=True)
class FaultConfig:
    """Declarative, seeded failure model (resolved by `fault_trace`).

    Fields:
      deorbit: ((sat, window), ...) — satellite `sat` is dead from
        `window` onward.
      launch: ((sat, window), ...) — satellite `sat` is alive from
        `window` onward. A satellite whose *first* event is a launch starts
        the run dead (a late addition to the constellation); a
        deorbit-then-launch pair models an outage with recovery. Events
        apply in window order.
      outages: ((station, start, end), ...) — ground station `station` is
        down for windows ``[start, end)``.
      rate_scale_min / rate_scale_max: bounds of the seeded per-block
        uniform link-rate multiplier ("weather"). The default (1, 1) draws
        nothing; link-budget grants are scaled by the draw (geometry-only
        runs have no grants to scale, so the multiplier is inert there).
      rate_block: windows per weather draw (weather persists; 8 windows =
        2 h at T0 = 15 min).
      seed: the weather RNG seed — the whole trace is a pure function of
        the config.
      oracle: scheduler visibility. False (default, *blind*): schedulers
        and the FedSpace search plan on the clean connectivity while the
        engine executes the faulted one — the realistic case. True
        (*oracle*): planning sees the faulted artifacts too.

    A default-constructed config is `trivial` and resolves to no trace at
    all (`Federation` then wires the run exactly as ``faults=None``).
    """
    deorbit: Tuple[Tuple[int, int], ...] = ()
    launch: Tuple[Tuple[int, int], ...] = ()
    outages: Tuple[Tuple[int, int, int], ...] = ()
    rate_scale_min: float = 1.0
    rate_scale_max: float = 1.0
    rate_block: int = 8
    seed: int = 0
    oracle: bool = False

    def __post_init__(self):
        for name in ("deorbit", "launch"):
            for j, (sat, window) in enumerate(getattr(self, name)):
                if sat < 0:
                    raise ValueError(
                        f"FaultConfig.{name}[{j}] satellite index must be "
                        f">= 0, got {sat}")
                if window < 0:
                    raise ValueError(
                        f"FaultConfig.{name}[{j}] epoch window must be "
                        f">= 0, got {window}")
        for j, (g, s, e) in enumerate(self.outages):
            if g < 0:
                raise ValueError(
                    f"FaultConfig.outages[{j}] station index must be >= 0, "
                    f"got {g}")
            if s < 0 or e < s:
                raise ValueError(
                    f"FaultConfig.outages[{j}] window range must satisfy "
                    f"0 <= start <= end, got ({s}, {e})")
        if not 0.0 <= self.rate_scale_min <= self.rate_scale_max:
            raise ValueError(
                "FaultConfig.rate_scale_min/rate_scale_max must satisfy "
                f"0 <= min <= max, got ({self.rate_scale_min}, "
                f"{self.rate_scale_max})")
        if self.rate_block < 1:
            raise ValueError(
                f"FaultConfig.rate_block must be >= 1, got "
                f"{self.rate_block}")

    @property
    def trivial(self) -> bool:
        """True when the config injects nothing — `Federation` then skips
        trace resolution entirely, keeping the run on the exact
        ``faults=None`` code path (bit-identical by construction)."""
        return (not self.deorbit and not self.launch and not self.outages
                and self.rate_scale_min == 1.0
                and self.rate_scale_max == 1.0)


@dataclass(frozen=True)
class FaultTrace:
    """A config resolved against a horizon: deterministic per-window masks.

    Fields:
      alive: (W, K) bool — satellite exists this window.
      station_up: (W, G) bool — ground station is serving this window
        (G = 0 when the trace was built without station information).
      rate_scale: (W,) float32 — link-rate multiplier (weather).
      reach: optional (W, K) bool — satellite sees at least one *up*
        station this window (folds outages into station-collapsed
        connectivity; built when `fault_trace` is given per-station
        contact counts, None otherwise).
      oracle: scheduler visibility, copied from the config.

    Derived views: `mask` (alive ∧ reach — the connectivity multiplier)
    and `revive` (dead→alive transitions — where the engine applies
    `fault_reset`'s forced re-download).
    """
    alive: np.ndarray
    station_up: np.ndarray
    rate_scale: np.ndarray
    reach: Optional[np.ndarray] = None
    oracle: bool = False

    @property
    def num_windows(self) -> int:
        return self.alive.shape[0]

    @property
    def mask(self) -> np.ndarray:
        """(W, K) bool connectivity multiplier: alive and (when station
        information was resolved) able to reach an up station."""
        return self.alive if self.reach is None \
            else self.alive & self.reach

    @property
    def revive(self) -> np.ndarray:
        """(W, K) bool: satellite transitions dead → alive at this window
        (launches after the start of the run, recoveries). Row 0 is all
        False — satellites alive from the start keep their bootstrap
        state."""
        prev = np.concatenate([self.alive[:1], self.alive[:-1]], axis=0)
        return self.alive & ~prev

    def extended(self, num_windows: int) -> "FaultTrace":
        """The trace padded to `num_windows` by persisting the final row
        (a deorbited satellite stays dead, an outage that covers the tail
        stays dark, the last weather draw holds). Faults are calendar
        events over absolute windows, so `repeat_connectivity` tiling of
        `C` deliberately does NOT tile the trace."""
        W = self.num_windows
        if num_windows <= W:
            return self

        def pad(arr):
            return np.concatenate(
                [arr, np.repeat(arr[-1:], num_windows - W, axis=0)], axis=0)

        return dataclasses.replace(
            self, alive=pad(self.alive), station_up=pad(self.station_up),
            rate_scale=pad(self.rate_scale),
            reach=None if self.reach is None else pad(self.reach))


def fault_trace(config: FaultConfig, num_windows: int, *, K: int,
                num_stations: Optional[int] = None,
                counts: Optional[np.ndarray] = None) -> FaultTrace:
    """Resolve a `FaultConfig` into a deterministic `FaultTrace`.

    Args:
      config: the declarative failure model.
      num_windows: the horizon W the trace covers.
      K: constellation size (satellite indices are validated against it).
      num_stations: ground-network size G for the station-up mask
        (defaults to ``counts.shape[2]`` when counts are given, else 0;
        required when the config declares station outages).
      counts: optional (>= W, K, G) per-window per-pair contact counts
        (`repro.core.connectivity.station_windows`) — when given, the
        trace also carries `reach`, so station outages apply to
        station-collapsed geometry connectivity, not only to budgets.

    Pure: same (config, horizon, counts) → bit-identical trace.
    """
    W = int(num_windows)
    if counts is not None:
        counts = np.asarray(counts)
        if counts.shape[0] < W:
            raise ValueError(
                f"counts covers {counts.shape[0]} windows < horizon {W}")
        if num_stations is None:
            num_stations = counts.shape[2]
    G = int(num_stations or 0)
    if config.outages and G == 0:
        raise ValueError(
            "FaultConfig.outages requires station information: pass "
            "num_stations= (or counts=) to fault_trace")

    events = sorted(
        [(w, 0, k) for k, w in config.deorbit]
        + [(w, 1, k) for k, w in config.launch])
    for w, _, k in events:
        if k >= K:
            raise ValueError(
                f"FaultConfig satellite index {k} out of range for K={K}")
    # a satellite whose first event is a launch starts the run dead
    first_kind = {}
    for w, kind, k in events:
        first_kind.setdefault(k, kind)
    alive = np.ones((W, K), bool)
    for k, kind in first_kind.items():
        if kind == 1:
            alive[:, k] = False
    for w, kind, k in events:
        if w < W:
            alive[w:, k] = kind == 1

    station_up = np.ones((W, G), bool)
    for g, s, e in config.outages:
        if g >= G:
            raise ValueError(
                f"FaultConfig station index {g} out of range for G={G}")
        station_up[s:min(e, W), g] = False

    rate_scale = np.ones(W, np.float32)
    if (config.rate_scale_min, config.rate_scale_max) != (1.0, 1.0):
        rng = np.random.default_rng(config.seed)
        nblocks = -(-W // config.rate_block)
        draws = rng.uniform(config.rate_scale_min, config.rate_scale_max,
                            nblocks).astype(np.float32)
        rate_scale = np.repeat(draws, config.rate_block)[:W]

    reach = None
    if counts is not None and G > 0:
        reach = ((counts[:W] > 0) & station_up[:, None, :]).any(axis=-1)
    return FaultTrace(alive=alive, station_up=station_up,
                      rate_scale=rate_scale, reach=reach,
                      oracle=config.oracle)


# ---------------------------------------------------------------------------
# Pure transforms over the existing connectivity artifacts. Nothing here
# re-solves contention or re-propagates orbits: faults *mask* what the
# clean world already resolved (a satellite whose assigned station goes
# dark loses that window's contact — stations do not re-bid for it, which
# keeps execution a deterministic function of (clean artifacts, trace)).


def mask_connectivity(C: np.ndarray, trace: FaultTrace) -> np.ndarray:
    """Fault-masked geometry connectivity: ``C ∧ trace.mask`` (dead
    satellites lose every contact; with station information resolved,
    windows whose only visible stations are down drop out too)."""
    C = np.asarray(C, bool)
    return C & trace.extended(C.shape[0]).mask[:C.shape[0]]


def mask_served(served: np.ndarray, grants: np.ndarray, assign: np.ndarray,
                trace: FaultTrace):
    """Fault-masked (served, grants) arrays of a resolved link budget:
    a contact survives iff the satellite is alive and its *assigned*
    station is up; surviving grants are rescaled by the weather
    multiplier (``floor(grants * rate_scale)`` — a degraded pass can drop
    below a transfer's unit needs, which is the point)."""
    served = np.asarray(served, bool)
    W = served.shape[0]
    tr = trace.extended(W)
    ok = tr.alive[:W]
    if tr.station_up.shape[1]:
        up = np.take_along_axis(tr.station_up[:W],
                                np.maximum(assign, 0), axis=1)
        ok = ok & np.where(assign >= 0, up, False)
    served2 = served & ok
    grants2 = np.where(
        served2,
        np.floor(grants * tr.rate_scale[:W, None]).astype(np.int32),
        0).astype(np.int32)
    return served2, grants2


def mask_budget(budget: LinkBudget, trace: FaultTrace) -> LinkBudget:
    """The pure fault transform over a resolved `LinkBudget`: `visible`
    masked by aliveness, `served`/`grants` by `mask_served`, `assign`
    cleared where service was lost. Unit needs are untouched — weather
    scales what a window *delivers*, not what a transfer *costs*."""
    served2, grants2 = mask_served(budget.served, budget.grants,
                                   budget.assign, trace)
    W = budget.served.shape[0]
    alive = trace.extended(W).alive[:W]
    return LinkBudget(
        visible=np.asarray(budget.visible, bool) & alive, served=served2,
        assign=np.where(served2, budget.assign, -1).astype(np.int32),
        grants=grants2, need_up=budget.need_up, need_dn=budget.need_dn)


def fault_reset(state: SS.SatState, revive) -> SS.SatState:
    """The re-entry transition: satellites reviving this window (launched,
    or recovered from an outage) reset to "never received" —
    version/pending -1, transfer progress and relay units 0 — which forces
    a model download before they can train or upload again, so a
    recovered satellite never contributes a round based on a pre-outage
    model. GS-side state (`buffered`) is untouched: an update that reached
    the buffer before the failure is already the ground segment's.
    Pure masked `jnp.where` updates, dtype-preserving, idempotent."""
    version = jnp.where(revive, SS._m1(state.version), state.version)
    pending = jnp.where(revive, SS._m1(state.pending), state.pending)
    progress = None if state.progress is None else jnp.where(
        revive, jnp.asarray(0, state.progress.dtype), state.progress)
    relay = None if state.relay is None else jnp.where(
        revive, jnp.asarray(0, state.relay.dtype), state.relay)
    return SS.SatState(version, pending, state.buffered, progress, relay)


# ---------------------------------------------------------------------------
# Scenario helpers (the robustness study's fault generators).


def random_churn(K: int, num_windows: int, fraction: float, *,
                 seed: int = 0) -> Tuple[Tuple[int, int], ...]:
    """Seeded churn events: ``floor(K * fraction)`` distinct satellites
    deorbit at uniform windows in ``[1, num_windows)``. Deterministic in
    (K, num_windows, fraction, seed) — escalating-churn studies sweep
    `fraction` under one seed so fault sets are nested-ish and curves are
    comparable."""
    n = int(K * fraction)
    if n <= 0:
        return ()
    rng = np.random.default_rng(seed)
    sats = rng.permutation(K)[:n]
    windows = rng.integers(1, max(num_windows, 2), n)
    return tuple(sorted((int(k), int(w)) for k, w in zip(sats, windows)))


def station_blackout(num_stations: int, start: int,
                     end: int) -> Tuple[Tuple[int, int, int], ...]:
    """Outage entries taking the whole ground network down for
    ``[start, end)`` — the total-blackout scenario of the robustness
    study."""
    return tuple((g, int(start), int(end)) for g in range(num_stations))
