from repro.core.connectivity import (ConstellationSpec, connectivity_sets,
                                     connectivity_stats)
from repro.core.scheduler import (AsyncScheduler, FedBuffScheduler,
                                  FedSpaceScheduler, PeriodicScheduler,
                                  Scheduler, SyncScheduler, make_scheduler)
from repro.core.staleness import (SatState, bootstrap_state, init_state,
                                  simulate_candidates, simulate_window,
                                  staleness_compensation, step)
from repro.core.aggregation import aggregation_weights, apply_aggregation
