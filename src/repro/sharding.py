"""Sharding rules: parameter, optimizer-state, activation, and decode-cache
PartitionSpecs for the production meshes (see DESIGN.md §5).

Axes: 'model' = tensor parallel, 'data' = data parallel, 'pod' = pod axis
(multi-pod only). Batch/tokens shard over the data axes; 2-D weight matrices
shard their wide dim over 'model'; MoE expert stacks shard the expert dim
over 'model' when divisible (else per-expert d_ff); optimizer moments get an
extra 'data' axis (ZeRO-1) on the first divisible dim.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# Leaf-name → role. Roles: col (shard output dim), row (shard input dim),
# vocab_in, replicate.
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_x", "router",
        "vis_proj", "conv_w", "w_a", "w_i"}
_ROW = {"wo", "w_down", "out_proj"}


def _data_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _model_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _leaf_name(path) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return e.key
    return ""


def _in_stage(path) -> bool:
    return any(isinstance(e, jax.tree_util.DictKey) and e.key == "stages"
               for e in path) or any(
        isinstance(e, jax.tree_util.SequenceKey) for e in path)


def param_spec(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    name = _leaf_name(path)
    ndim = leaf.ndim
    stacked = _in_stage(path) and name not in ("embed", "lm_head")
    base = ndim - (1 if stacked else 0)
    ms = _model_size(mesh)

    def ok(dim_size):
        return dim_size % ms == 0

    spec = [None] * ndim
    if name == "embed" and ndim == 2:
        if ok(leaf.shape[0]):
            spec[0] = "model"
    elif name == "lm_head" and ndim == 2:
        if ok(leaf.shape[1]):
            spec[1] = "model"
    elif name in ("w_gate", "w_up", "w_down") and base == 3:
        # MoE expert stack (E, D, F) / (E, F, D)
        e_dim = ndim - 3
        if ok(leaf.shape[e_dim]):
            spec[e_dim] = "model"               # expert parallel
        elif name in ("w_gate", "w_up") and ok(leaf.shape[ndim - 1]):
            spec[ndim - 1] = "model"            # mixtral: shard d_ff
        elif name == "w_down" and ok(leaf.shape[ndim - 2]):
            spec[ndim - 2] = "model"
    elif name in _COL and base == 2:
        if ok(leaf.shape[ndim - 1]):
            spec[ndim - 1] = "model"
    elif name in _ROW and base == 2:
        if ok(leaf.shape[ndim - 2]):
            spec[ndim - 2] = "model"
    return P(*spec)


def param_specs(params_shape, cfg: ModelConfig, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, cfg, mesh), params_shape)


def opt_spec_from_param(pspec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: additionally shard the first unsharded, divisible dim of the
    AdamW moments over 'data'."""
    ds = mesh.shape["data"]
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (s, dim) in enumerate(zip(spec, shape)):
        if s is None and dim % ds == 0 and dim >= ds:
            spec[i] = "data"
            break
    return P(*spec)


def opt_state_specs(opt_shape, pspecs, cfg: ModelConfig, mesh: Mesh):
    def for_moment(tree_shape):
        return jax.tree.map(
            lambda leaf, ps: opt_spec_from_param(ps, leaf.shape, mesh),
            tree_shape, pspecs)
    return {
        "step": P(),
        "m": for_moment(opt_shape["m"]),
        "v": for_moment(opt_shape["v"]),
    }


def batch_specs(batch_shape, mesh: Mesh):
    """Shard the leading (batch) dim of every input over the data axes when
    divisible."""
    dp = _data_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in dp]))

    def spec(leaf):
        if leaf.ndim >= 1 and leaf.shape[0] % n == 0 and leaf.shape[0] >= n:
            return P(dp, *([None] * (leaf.ndim - 1)))
        return P(*([None] * leaf.ndim))

    return jax.tree.map(spec, batch_shape)


# §Perf decode optimization (EXPERIMENTS.md hillclimb B): additionally shard
# the cache feature dim (head_dim / state channels) over 'model' so the
# scanned cache carry never gets all-gathered, and keep serve-step logits
# vocab-sharded. Baseline (False) keeps the first-recorded lowering.
DECODE_OPT = False


def decode_state_specs(state_shape, cfg: ModelConfig, mesh: Mesh,
                       shape: ShapeConfig):
    """Decode caches: batch over data axes when divisible; for B=1 long
    decode, shard large cache sequence dims over 'data' instead. With
    DECODE_OPT, the trailing feature dim also shards over 'model'."""
    dp = _data_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in dp]))
    ds = mesh.shape["data"]
    ms = mesh.shape["model"]

    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        shp = leaf.shape
        out = [None] * leaf.ndim
        # stacked leading layer axis for stage caches
        start = 1 if _in_stage(path) and leaf.ndim >= 2 else 0
        bdim = start
        if bdim < leaf.ndim and shp[bdim] % n == 0 and shp[bdim] >= n:
            out[bdim] = dp
        elif leaf.ndim >= start + 2:
            # batch too small: shard the longest remaining dim (seq) on data
            cand = max(range(start, leaf.ndim), key=lambda i: shp[i])
            if shp[cand] % ds == 0 and shp[cand] >= 16384:
                out[cand] = "data"
        if DECODE_OPT and leaf.ndim >= start + 2 \
                and shp[-1] % ms == 0 and out[leaf.ndim - 1] is None:
            out[leaf.ndim - 1] = "model"
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, state_shape)


def to_named(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
