"""Checkpointing: pytree save/load on npz plus the version-indexed
trajectory stores (`CheckpointStore` on host, `DeviceCheckpointStore` as
a device-resident ring buffer) the FL engine and the utility estimator
read model versions from."""
from repro.ckpt.checkpoint import (CheckpointStore, DeviceCheckpointStore,
                                   load_pytree, save_pytree)

__all__ = ["CheckpointStore", "DeviceCheckpointStore", "load_pytree",
           "save_pytree"]
