from repro.ckpt.checkpoint import (CheckpointStore, DeviceCheckpointStore,
                                   load_pytree, save_pytree)
