from repro.ckpt.checkpoint import (CheckpointStore, load_pytree, save_pytree)
