"""Pytree checkpointing on npz (no external deps).

Flattens a pytree to path-keyed arrays; restores with the original treedef.
Also provides the bounded in-memory/off-memory trajectory store the utility
estimator consumes ({w^0..w^Imax}, paper §3.2).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten_with_paths(tree))


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of `like` (shapes must match)."""
    data = np.load(path)
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(flat[1], leaves)


class CheckpointStore:
    """Version-indexed global-model store. Keeps the newest `keep_in_memory`
    versions in RAM and (optionally) spills every `spill_every`-th version to
    disk — the utility estimator needs w^{i-s} for s <= s_max, the FL server
    needs old bases for stale satellites."""

    def __init__(self, directory: Optional[str] = None,
                 keep_in_memory: int = 32, spill_every: int = 0):
        self.dir = directory
        self.keep = keep_in_memory
        self.spill_every = spill_every
        self._mem: Dict[int, Any] = {}
        self._disk: Dict[int, str] = {}
        self._like = None

    def put(self, version: int, params) -> None:
        self._like = params
        self._mem[version] = params
        if self.dir and self.spill_every and version % self.spill_every == 0:
            p = os.path.join(self.dir, f"w_{version:06d}.npz")
            save_pytree(p, params)
            self._disk[version] = p

    def prune(self, min_referenced: int) -> None:
        """Drop in-memory versions older than the oldest still-referenced
        base (callers pass min over satellites' pending/buffered bases), but
        never shrink below `keep` recent versions."""
        if not self._mem:
            return
        newest = max(self._mem)
        cutoff = min(min_referenced, newest - self.keep + 1)
        for v in [v for v in self._mem if v < cutoff]:
            del self._mem[v]

    def get(self, version: int):
        if version in self._mem:
            return self._mem[version]
        if version in self._disk:
            return load_pytree(self._disk[version], self._like)
        raise KeyError(f"version {version} evicted "
                       f"(have {sorted(self._mem)[:4]}..)")

    def versions(self) -> List[int]:
        return sorted(set(self._mem) | set(self._disk))
