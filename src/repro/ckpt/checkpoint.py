"""Pytree checkpointing on npz (no external deps).

Flattens a pytree to path-keyed arrays; restores with the original treedef.
Also provides the bounded in-memory/off-memory trajectory store the utility
estimator consumes ({w^0..w^Imax}, paper §3.2) and its device-resident
sibling `DeviceCheckpointStore` — a stacked-pytree ring buffer the FL
engine reads base checkpoints from without a host→device transfer.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_pytree(path: str, tree) -> None:
    """Save a pytree of arrays to `path` as an npz of path-keyed leaves
    (parent directories are created; see `load_pytree` to restore)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten_with_paths(tree))


def load_pytree(path: str, like) -> Any:
    """Restore into the structure of `like` (shapes must match)."""
    data = np.load(path)
    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat[0]:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(flat[1], leaves)


class CheckpointStore:
    """Version-indexed global-model store. Keeps the newest `keep_in_memory`
    versions in RAM and (optionally) spills every `spill_every`-th version to
    disk — the utility estimator needs w^{i-s} for s <= s_max, the FL server
    needs old bases for stale satellites."""

    def __init__(self, directory: Optional[str] = None,
                 keep_in_memory: int = 32, spill_every: int = 0):
        self.dir = directory
        self.keep = keep_in_memory
        self.spill_every = spill_every
        self._mem: Dict[int, Any] = {}
        self._disk: Dict[int, str] = {}
        self._like = None

    def put(self, version: int, params) -> None:
        """Store `params` (a pytree) under integer `version`; spills to
        disk as well when the version hits the `spill_every` stride."""
        self._like = params
        self._mem[version] = params
        if self.dir and self.spill_every and version % self.spill_every == 0:
            p = os.path.join(self.dir, f"w_{version:06d}.npz")
            save_pytree(p, params)
            self._disk[version] = p

    def prune(self, min_referenced: int) -> None:
        """Drop versions older than the oldest still-referenced base
        (callers pass min over satellites' pending/buffered bases), but
        never shrink below `keep` recent versions. The cutoff applies to
        the disk spill too — spilled ``.npz`` files are unlinked, so long
        runs with `spill_every` set stay disk-bounded."""
        if not self._mem:
            return
        newest = max(self._mem)
        cutoff = min(min_referenced, newest - self.keep + 1)
        for v in [v for v in self._mem if v < cutoff]:
            del self._mem[v]
        for v in [v for v in self._disk if v < cutoff]:
            try:
                os.unlink(self._disk[v])
            except OSError:
                pass
            del self._disk[v]

    def get(self, version: int):
        """Fetch the stored pytree for `version` (memory first, then the
        disk spill). Raises KeyError for evicted/unknown versions."""
        if version in self._mem:
            return self._mem[version]
        if version in self._disk:
            return load_pytree(self._disk[version], self._like)
        raise KeyError(f"version {version} evicted "
                       f"(have {sorted(self._mem)[:4]}..)")

    def versions(self) -> List[int]:
        """Sorted list of every retrievable version (memory + disk)."""
        return sorted(set(self._mem) | set(self._disk))


# ---------------------------------------------------------------------------
# Device-resident store


@functools.partial(jax.jit, donate_argnums=(0,))
def _ring_write(ring, params, slot):
    """Write `params` into ring slot `slot` (traced, so one compiled
    program serves every slot). The ring argument is donated: XLA aliases
    the output to the input buffer, so the write is in place — no
    O(ring · model) copy per put."""
    return jax.tree.map(
        lambda b, l: jax.lax.dynamic_update_index_in_dim(
            b, l.astype(b.dtype), slot, 0), ring, params)


@jax.jit
def _ring_read(ring, slot):
    return jax.tree.map(lambda b: jax.lax.dynamic_index_in_dim(
        b, slot, 0, keepdims=False), ring)


@jax.jit
def _ring_gather(ring, slots):
    return jax.tree.map(lambda b: jnp.take(b, slots, axis=0), ring)


class DeviceCheckpointStore:
    """Device-resident `CheckpointStore`: the newest `ring` versions live
    as one stacked pytree on device (leading axis = ring slot) and are
    gathered by version index there, so `get()` of a recent version — the
    FL server fetching w^{i-s} for a stale satellite — returns device
    arrays with no host→device transfer. Versions evicted from the ring
    while still retained spill to host memory (and optionally disk, same
    `spill_every` policy), behind the same put/get/prune/versions contract.

    Size the ring to s_max plus margin: Algorithm 1 references bases at
    most `prune`'s retention window deep, so in steady state every
    `get` is served from device."""

    def __init__(self, ring: int = 34, directory: Optional[str] = None,
                 spill_every: int = 0):
        self.keep = ring
        self.dir = directory
        self.spill_every = spill_every
        self._ring = None                       # stacked pytree, axis0=ring
        self._slot_ver: List[Optional[int]] = [None] * ring
        self._ver_slot: Dict[int, int] = {}
        self._host: Dict[int, Any] = {}         # spilled host pytrees
        self._disk: Dict[int, str] = {}
        self._like = None

    def put(self, version: int, params) -> None:
        """Write `params` into the ring slot for `version` (an in-place
        donated device write); a still-retained version occupying the slot
        is spilled to host first. Disk spill follows `spill_every`."""
        params = jax.tree.map(jnp.asarray, params)
        self._like = params
        if self._ring is None:
            self._ring = jax.tree.map(
                lambda l: jnp.zeros((self.keep,) + l.shape, l.dtype),
                params)
        slot = version % self.keep
        evicted = self._slot_ver[slot]
        if evicted is not None and evicted != version \
                and evicted in self._ver_slot:
            # still retained (not pruned): spill to host before overwrite
            self._host[evicted] = jax.tree.map(
                np.asarray, _ring_read(self._ring, jnp.int32(slot)))
            del self._ver_slot[evicted]
        self._ring = _ring_write(self._ring, params, jnp.int32(slot))
        self._ver_slot[version] = slot
        self._slot_ver[slot] = version
        self._host.pop(version, None)
        if self.dir and self.spill_every and version % self.spill_every == 0:
            p = os.path.join(self.dir, f"w_{version:06d}.npz")
            save_pytree(p, params)
            self._disk[version] = p

    def get(self, version: int):
        """Fetch `version` as device arrays: a device gather when it is
        still in the ring, else re-upload from the host/disk spill.
        Raises KeyError for evicted/unknown versions."""
        slot = self._ver_slot.get(version)
        if slot is not None:
            return _ring_read(self._ring, jnp.int32(slot))
        if version in self._host:
            return jax.tree.map(jnp.asarray, self._host[version])
        if version in self._disk:
            return jax.tree.map(jnp.asarray,
                                load_pytree(self._disk[version], self._like))
        raise KeyError(f"version {version} evicted "
                       f"(have {self.versions()[:4]}..)")

    def get_many(self, versions):
        """Stacked device gather of several in-ring versions (leading axis
        = len(versions)); falls back to per-version `get` + stack when any
        requested version has spilled off the ring."""
        slots = [self._ver_slot.get(v) for v in versions]
        if all(s is not None for s in slots):
            return _ring_gather(self._ring,
                                jnp.asarray(slots, jnp.int32))
        trees = [self.get(v) for v in versions]
        return jax.tree.map(lambda *ls: jnp.stack(ls), *trees)

    def prune(self, min_referenced: int) -> None:
        """Same retention rule as `CheckpointStore.prune`, applied to ring
        bookkeeping, host spill, and disk spill (files unlinked)."""
        known = list(self._ver_slot) + list(self._host)
        if not known:
            return
        newest = max(known)
        cutoff = min(min_referenced, newest - self.keep + 1)
        for v in [v for v in self._ver_slot if v < cutoff]:
            self._slot_ver[self._ver_slot.pop(v)] = None
        for v in [v for v in self._host if v < cutoff]:
            del self._host[v]
        for v in [v for v in self._disk if v < cutoff]:
            try:
                os.unlink(self._disk[v])
            except OSError:
                pass
            del self._disk[v]

    def versions(self) -> List[int]:
        """Sorted list of every retrievable version (ring + spills)."""
        return sorted(set(self._ver_slot) | set(self._host)
                      | set(self._disk))
