from repro.configs.base import (INPUT_SHAPES, ModelConfig, ShapeConfig,
                                StageSpec, get_config, list_configs, register)
