"""Minitron-4B — width/depth-pruned Nemotron [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24, num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    stages=(StageSpec(("global",), 32),),
    citation="arXiv:2407.14679",
))
