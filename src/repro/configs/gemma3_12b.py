"""Gemma3-12B — 5:1 local:global interleave, 128k context
[hf:google/gemma-3-1b-pt family]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16, num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    stages=(StageSpec(("local", "local", "local", "local", "local", "global"), 8),),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    citation="hf:google/gemma-3-1b-pt",
    supports_long_decode=True,
))
