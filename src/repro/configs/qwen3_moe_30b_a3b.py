"""Qwen3-MoE-30B-A3B — 128 experts, top-8 routing [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32, num_kv_heads=4,
    d_ff=768,                      # per-expert FFN width
    vocab_size=151936,
    stages=(StageSpec(("global",), 48),),
    qk_norm=True,
    head_dim=128,
    num_experts=128,
    experts_per_token=8,
    citation="hf:Qwen/Qwen3-30B-A3B",
))
