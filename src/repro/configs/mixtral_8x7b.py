"""Mixtral-8x7B — 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32, num_kv_heads=8,
    d_ff=14336,                    # per-expert FFN width
    vocab_size=32000,
    stages=(StageSpec(("local",), 32),),
    window_size=4096,
    num_experts=8,
    experts_per_token=2,
    citation="arXiv:2401.04088",
    supports_long_decode=True,
))
