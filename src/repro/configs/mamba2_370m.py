"""Mamba2-370M — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=1, num_kv_heads=1,   # attention-free
    d_ff=0,
    vocab_size=50280,
    stages=(StageSpec(("ssm",), 48),),
    ssm_state_dim=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
    citation="arXiv:2405.21060",
    supports_long_decode=True,
))
