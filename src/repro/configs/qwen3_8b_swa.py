"""Qwen3-8B-SWA — beyond-paper sliding-window retrofit of qwen3-8b so a pure
full-attention dense arch can exercise long_500k decode (see DESIGN.md)."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="qwen3-8b-swa",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32, num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    stages=(StageSpec(("local",), 36),),
    window_size=4096,
    qk_norm=True,
    citation="hf:Qwen/Qwen3-8B (windowed variant, ours)",
    supports_long_decode=True,
))
