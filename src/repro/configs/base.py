"""Configuration system: model configs, input shapes, and the arch registry.

Every assigned architecture registers a ``ModelConfig`` here via its
``src/repro/configs/<id>.py`` module.  Configs are frozen dataclasses so they
hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds usable in a layer pattern.
GLOBAL_ATTN = "global"      # full causal attention
LOCAL_ATTN = "local"        # sliding-window causal attention
RECURRENT = "recurrent"     # RG-LRU recurrent block
SSM = "ssm"                 # Mamba-2 SSD block
ENC_ATTN = "enc"            # bidirectional encoder self-attention
CROSS_ATTN = "cross"        # decoder layer with self(causal) + cross attention


@dataclass(frozen=True)
class StageSpec:
    """A scanned group of layers: ``pattern`` repeated ``repeats`` times."""
    pattern: Tuple[str, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str            # dense | moe | ssm | hybrid | vlm | audio | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- architecture of the stack ---
    stages: Tuple[StageSpec, ...] = ()
    head_dim: Optional[int] = None
    window_size: int = 4096           # for LOCAL_ATTN layers
    qk_norm: bool = False
    mlp_act: str = "swiglu"           # swiglu | gelu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # --- SSM (mamba2) ---
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # --- RG-LRU (recurrentgemma) ---
    lru_width: int = 0
    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500           # nominal frame count of the audio stub
    decoder_prompt: int = 448         # decoder token budget for train/prefill
    # --- modality frontends (stubs; see DESIGN.md) ---
    frontend: Optional[str] = None    # None | "vision" | "audio"
    num_image_tokens: int = 576       # vision stub patch-embedding count
    # --- numerics ---
    param_dtype: str = "bfloat16"
    # --- provenance ---
    citation: str = ""
    # --- capability flags ---
    supports_long_decode: bool = False   # sub-quadratic decode state?
    is_encoder_decoder: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        out = []
        for st in self.stages:
            out.extend(list(st.pattern) * st.repeats)
        return tuple(out)

    def validate(self) -> None:
        assert sum(s.num_layers for s in self.stages) == self.num_layers, (
            self.name, sum(s.num_layers for s in self.stages), self.num_layers)
        if self.num_experts:
            assert self.experts_per_token > 0
        assert self.num_heads % max(self.num_kv_heads, 1) == 0

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests (<=2 layers,
        d_model<=512, <=4 experts)."""
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv = min(self.num_kv_heads, max(1, num_heads // 2))
        num_kv = num_heads // max(1, num_heads // num_kv)
        # Keep one repeat of each stage pattern, at most 2 layers total.
        stages = []
        total = 0
        for st in self.stages:
            pat = st.pattern[: max(1, 2 - total)]
            if not pat:
                break
            stages.append(StageSpec(pattern=tuple(pat), repeats=1))
            total += len(pat)
            if total >= 2:
                break
        kw = dict(
            name=self.name + "-smoke",
            num_layers=sum(s.num_layers for s in stages),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=max(1, num_kv),
            d_ff=min(self.d_ff, 512) or 0,
            vocab_size=min(self.vocab_size, 512),
            stages=tuple(stages),
            head_dim=None,
            window_size=min(self.window_size, 64),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ssm_state_dim=min(self.ssm_state_dim, 16),
            ssm_head_dim=min(self.ssm_head_dim, 16),
            ssm_chunk=32,
            lru_width=min(self.lru_width, d_model),
            encoder_layers=min(self.encoder_layers, 1),
            encoder_seq=32,
            decoder_prompt=16,
            num_image_tokens=8,
            param_dtype="float32",
        )
        kw.update(overrides)
        cfg = dataclasses.replace(self, **kw)
        cfg.validate()
        return cfg


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
    # extra (not part of the assigned 10x4 grid): the paper's eq. 4 buffered
    # aggregation at datacenter scale — global_batch = buffer size M
    "agg_m96": ShapeConfig("agg_m96", 0, 96, "agg"),
    # full FL round: M=16 buffered client rounds replayed + aggregated
    "flround_m16": ShapeConfig("flround_m16", 512, 16, "flround"),
}

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    cfg.validate()
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False

_CONFIG_MODULES = [
    "mamba2_370m", "h2o_danube_1_8b", "phi3_vision_4_2b", "qwen3_moe_30b_a3b",
    "qwen3_8b", "gemma3_12b", "recurrentgemma_9b", "minitron_4b",
    "whisper_base", "mixtral_8x7b", "densenet_fl", "qwen3_8b_swa",
]


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    import importlib
    for m in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _LOADED = True
