"""Phi-3-Vision-4.2B — phi3-mini decoder + CLIP vision stub
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32, num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    stages=(StageSpec(("global",), 32),),
    frontend="vision",
    num_image_tokens=576,
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
))
