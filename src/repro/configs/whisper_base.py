"""Whisper-base — encoder-decoder backbone; conv/mel frontend is a stub that
feeds precomputed frame embeddings [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,                  # decoder layers
    d_model=512,
    num_heads=8, num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    stages=(StageSpec(("cross",), 6),),
    encoder_layers=6,
    encoder_seq=1500,
    decoder_prompt=448,
    mlp_act="gelu",
    frontend="audio",
    is_encoder_decoder=True,
    citation="arXiv:2212.04356",
))
