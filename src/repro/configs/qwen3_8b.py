"""Qwen3-8B — dense GQA decoder with qk-norm [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="qwen3-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32, num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    stages=(StageSpec(("global",), 36),),
    qk_norm=True,
    citation="hf:Qwen/Qwen3-8B",
))
