"""H2O-Danube-1.8B — llama+mistral mix with sliding-window attention
[arXiv:2401.16818]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32, num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    stages=(StageSpec(("local",), 24),),
    window_size=4096,
    citation="arXiv:2401.16818",
    supports_long_decode=True,
))
