"""RecurrentGemma-9B — RG-LRU recurrent blocks + local attention, 2 recurrent
per 1 attention [arXiv:2402.19427]."""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16, num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    stages=(
        StageSpec(("recurrent", "recurrent", "local"), 12),
        StageSpec(("recurrent", "recurrent"), 1),
    ),
    window_size=2048,
    lru_width=4096,
    citation="arXiv:2402.19427",
    supports_long_decode=True,
))
