"""The paper's own FL model: a compact DenseNet-style CNN for fMoW-like
62-class image classification (DenseNet-161 in the paper; see DESIGN.md §7).

Handled by repro.models.densenet, not the transformer stack; registered here
so --arch densenet-fl selects it in the FL drivers.
"""
from repro.configs.base import ModelConfig, StageSpec, register

register(ModelConfig(
    name="densenet-fl",
    arch_type="cnn",
    num_layers=4,                  # dense blocks
    d_model=64,                    # growth rate
    num_heads=1, num_kv_heads=1,
    d_ff=0,
    vocab_size=62,                 # classes
    stages=(StageSpec(("cnn",), 4),),
    citation="Huang et al. 2017 (DenseNet); So et al. 2022 (FedSpace setup)",
))
