"""End-to-end driver (deliverable b), two halves of the same framework:

  Part A — the paper's experiment end-to-end: a DenseNet-style CNN (the
  paper's model family, frozen lower block included) federated across 48
  satellites with the FedSpace scheduler over simulated connectivity.

  Part B — the datacenter path: pretrain the FULL mamba2-370m config
  (368M parameters, ~100M-class scale) for a few hundred steps with the
  pjit train step — short sequences and small batch to fit the CPU budget;
  the 4k-seq/256-batch production shape is exercised by the dry-run.

Run:  PYTHONPATH=src python examples/satellite_fl_train.py [--part a|b|all]
"""
import argparse
import time

from repro.fl.api import (AdapterConfig, ConstellationConfig, DatasetConfig,
                          FLExperiment, Federation, PartitionConfig,
                          SchedulerConfig)
from repro.fl.engine import EngineConfig


def part_a():
    print("=== Part A: federated DenseNet (the paper's model family) ===")
    t0 = time.time()
    exp = FLExperiment(
        name="satellite_fl_densenet",
        constellation=ConstellationConfig(num_satellites=48, days=2.0),
        dataset=DatasetConfig(num_train=3000, num_val=600, image_size=16,
                              noise=1.0),
        partition=PartitionConfig(kind="noniid"),
        adapter=AdapterConfig(kind="densenet",
                              params={"growth": 8, "blocks": (2, 2, 2),
                                      "stem": 16,
                                      "frozen_blocks": 1}),  # paper §4.1
        scheduler=SchedulerConfig(
            kind="fedspace",
            params={"I0": 24, "n_min": 4, "n_max": 8,
                    "num_candidates": 300},
            setup={"pretrain_rounds": 10, "clients_per_round": 8,
                   "utility_samples": 40, "clients_per_sample": 6,
                   "local_steps": 8, "client_lr": 0.3}),
        train=EngineConfig(local_steps=8, client_lr=0.3, eval_every=24,
                           max_windows=144),
    )
    fed = Federation.from_experiment(exp)
    print(f"utility regressor "
          f"R^2={fed.scheduler_diag['r2_in_sample']:.2f}")
    res = fed.run()
    # NB: the compact CNN on noisy synthetic imagery needs thousands of
    # local steps to climb (chance = 1.6%); this 1.5-simulated-day demo
    # shows the full paper pipeline end-to-end — the calibrated
    # time-to-accuracy reproduction lives in benchmarks/table2 (MLP
    # adapter, 20-day horizon).
    print(f"accuracy curve: {[round(a, 3) for a in res.accuracy]}")
    print(f"global updates: {res.num_global_updates}, "
          f"aggregated gradients: {res.num_aggregated_gradients}")
    print(f"Part A done in {time.time() - t0:.0f}s\n")


def part_b(steps=None):
    print("=== Part B: datacenter pretraining of mamba2-370m (full "
          "368M-param config, short seq for CPU) ===")
    from repro.launch.train import train
    t0 = time.time()
    # 24 steps ≈ 15 min on CPU; scale --steps up on real hardware (the
    # few-hundred-step run is examples/satellite_fl_train.py --part b
    # --steps 300 on a pod; loss drops ~11.1 -> ~8.3 within 3 steps here)
    hist = train("mamba2-370m", reduced=False, steps=steps or 24, batch=4,
                 seq=64, lr=3e-4, log_every=4)
    print(f"loss {hist[0]:.3f} -> {hist[-1]:.3f} "
          f"({time.time() - t0:.0f}s)")
    assert hist[-1] < hist[0], "loss did not decrease"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--part", default="all", choices=["a", "b", "all"])
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    if args.part in ("a", "all"):
        part_a()
    if args.part in ("b", "all"):
        part_b(args.steps)


if __name__ == "__main__":
    main()
