"""Replanning as a service: fit the utility forest û once on flock191
(the "calibration" constellation), then serve eq.-13 schedule replans for
*other* constellations from long-lived `ReplanService` instances — no
refit per constellation.

Three pieces of the framework meet here:

* **Forest transfer** (`repro.core.utility.transfer_ready`): the search
  featurization depends only on `s_max`, never on the satellite count, so
  the flock191-fitted forest answers starlink40/120/400 requests
  unchanged; `transfer_report` shows how far each serving constellation
  sits outside the calibration envelope (trees saturate out there — see
  docs/replanning.md).
* **Delta-window scoring** (`repro.fl.replan.ReplanService`): consecutive
  aggregation events reuse the cached rollout prefix over the overlapping
  horizon and simulate only the newly revealed window, with `maintain()`
  run between requests so frontier upkeep stays off the answer path.
* **The persistent-jit serving pattern** (`examples/serve_decode.py`):
  one process, jitted kernels compiled per batch bucket on first use and
  reused for every later request.

    PYTHONPATH=src python examples/serve_replan.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import connectivity as CN
from repro.core import staleness as SS
from repro.core.utility import (RandomForestRegressor, featurize,
                                transfer_report)
from repro.fl.replan import ReplanService

S_MAX = 8
DAYS = 0.25                    # 24 fifteen-minute windows per preset


def _preset_hists(preset: str, s_max: int = S_MAX) -> np.ndarray:
    """Per-window staleness histograms from protocol rollouts of `preset`
    under a few periodic cadences (a spread of staleness mixes)."""
    C = CN.connectivity_sets(CN.constellation_preset(preset), days=DAYS)
    state = SS.bootstrap_state(C.shape[1])
    hists = []
    for period in (2, 3, 4, 6):
        a = (np.arange(C.shape[0]) % period == period - 1).astype(np.int32)
        _, _, infos = SS.simulate_window(
            jnp.asarray(C), jnp.asarray(a), state, jnp.int32(0),
            s_max=s_max, collect="hist")
        hists.append(np.asarray(infos["hist"]))
    return np.concatenate(hists).astype(np.float32)


def calibrate(s_max: int = S_MAX) -> RandomForestRegressor:
    """Fit û on flock191 rollouts against the staleness-discounted
    aggregate-mass curve (the synthetic stand-in for eq.-12 targets)."""
    H = _preset_hists("flock191", s_max)
    X = featurize(H, 1.0)
    s = np.arange(s_max + 1, dtype=np.float32)
    y = ((H * (1.2 - 0.3 * s)).sum(1)
         / np.maximum(H.sum(1), 1.0)).astype(np.float32)
    return RandomForestRegressor(n_trees=30, max_depth=6, seed=0).fit(X, y)


def serve(preset: str, rf: RandomForestRegressor, *, I0: int = 12,
          steps: int = 6, num_candidates: int = 2000):
    """One serving session: stream `steps` consecutive aggregation events
    for `preset` through a persistent service, realizing each returned
    schedule's first action against the true protocol state."""
    C = CN.connectivity_sets(CN.constellation_preset(preset), days=DAYS)
    K = C.shape[1]
    rep = transfer_report(rf, featurize(_preset_hists(preset), 1.0))
    print(f"{preset} (K={K}): in_envelope="
          f"{rep.get('in_envelope', 1.0):.2f}, "
          f"pred range [{rep['pred_min']:.3f}, {rep['pred_max']:.3f}]")

    svc = ReplanService(rf, I0=I0, num_candidates=num_candidates,
                        s_max=S_MAX, seed=0, min_pool=64)
    state = jax.tree.map(np.asarray, SS.bootstrap_state(K))
    ig = 0
    rng = np.random.default_rng(1)
    for i in range(steps):
        Cw = C[i:i + I0]
        t0 = time.perf_counter()
        plan = svc.replan(i, Cw, state, ig, 1.0, rng=rng)
        dt = (time.perf_counter() - t0) * 1e3
        print(f"  window {i:2d}: {svc.last_mode:5s} "
              f"{'(' + svc.last_reason + ')' if svc.last_reason else '':14s}"
              f"{dt:8.1f} ms  schedule={''.join(map(str, plan))}")
        svc.maintain()             # frontier upkeep between requests
        st, g, _ = SS.step(jax.tree.map(jnp.asarray, state), jnp.int32(ig),
                           jnp.asarray(C[i]), jnp.asarray(bool(plan[0])),
                           s_max=S_MAX, collect="none")
        state = jax.tree.map(np.asarray, st)
        ig = int(g)
    print(f"  stats: {svc.stats}")


def main():
    rf = calibrate()
    print(f"calibrated on flock191: {rf.n_trees} trees, "
          f"{rf.n_features_} features\n")
    for preset in ["starlink40", "starlink120", "starlink400"]:
        serve(preset, rf)
        print()


if __name__ == "__main__":
    main()
