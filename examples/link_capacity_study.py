"""Downlink-capacity study: the same constellation and protocol over the
dense 12-station network vs the single-station Svalbard network, with and
without finite link budgets.

Under the geometry-only contact model a ground network only changes *how
many* contacts happen. With finite uplink/downlink rates, a real model
size, and per-station concurrent-contact capacity (`LinkConfig`), the
sparse network additionally turns contacts away at the saturated station
and stretches every transfer over multiple passes — fewer aggregated
gradients, staler ones, and schedule searches that must plan around both.
This is the regime Matthiesen et al. (2022) and Razmi et al. (2021)
study, and what the "sparse1 vs dense12" comparison was built to show.

    PYTHONPATH=src python examples/link_capacity_study.py
"""
import dataclasses
import time

from repro.fl.api import (ConstellationConfig, DatasetConfig, FLExperiment,
                          Federation, LinkConfig, SchedulerConfig)
from repro.fl.engine import EngineConfig


def main():
    base = FLExperiment(
        name="link_capacity_study",
        constellation=ConstellationConfig(preset="starlink40", days=2.0),
        dataset=DatasetConfig(num_train=4000, num_val=800, noise=2.2),
        scheduler=SchedulerConfig(kind="fedbuff", params={"M": 10}),
        train=EngineConfig(local_steps=8, client_lr=1.0, eval_every=48,
                           max_windows=192),
    )
    # a 600 MB model over a 20 Mbit/s uplink needs 4 sixty-second contact
    # units, and each ground station serves one satellite at a time — the
    # saturated station turns a measurable share of geometric contacts away
    budget = LinkConfig(uplink_mbps=20.0, downlink_mbps=100.0,
                        model_mb=600.0, gs_capacity=1)

    print(f"{'ground':8s} {'links':12s} {'blocked':>7s} {'idle':>11s} "
          f"{'upd':>4s} {'grads':>6s}  staleness histogram (0..8+)")
    for ground in ("dense12", "sparse1"):
        for label, link in (("free", LinkConfig()), ("budget", budget)):
            exp = dataclasses.replace(
                base,
                constellation=dataclasses.replace(base.constellation,
                                                  ground=ground),
                link=link)
            t0 = time.time()
            fed = Federation.from_experiment(exp)
            res = fed.run()
            blocked = (f"{fed.link_budget.blocked_fraction():7.2f}"
                       if fed.link_budget is not None else "      -")
            print(f"{ground:8s} {label:12s} {blocked} "
                  f"{res.idle_connections:4d}/{res.total_connections:6d} "
                  f"{res.num_global_updates:4d} "
                  f"{res.num_aggregated_gradients:6d}  "
                  f"{res.staleness_hist.tolist()}  "
                  f"({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
