"""Robustness study: how gracefully does each scheduler degrade when the
world stops cooperating?

FedSpace plans on *deterministic* connectivity (§3.1). This study breaks
that premise the three ways production constellations do — satellites
deorbit mid-run (escalating churn), the whole ground network goes dark
for a stretch (blackout), and weather scales the link rates (degraded
passes) — and races sync / fedbuff / FedSpace / intra-plane sinks over
the same faulted worlds. Faults are *blind* by default: the schedulers
and the schedule search plan on the clean world while the engine
executes the faulted one, so the curves measure policy robustness, not
replanning. The final block flips FedSpace to the `oracle` view
(planning sees the faults) to show what perfect fault knowledge buys.

The base world is built ONCE (`Federation.from_experiment`, clean) and
every scenario derives from it via `Federation.with_faults` — the
constellation, contact artifacts, data, adapter, and ISL topology are
shared, only the resolved fault trace changes. All the sweepable
(scenario x policy) cells then run as a single batched dispatch through
`repro.fl.sweep.run_sweep`; the protocol counters are bit-identical to
the sequential runs (that is the sweep module's parity contract), so
only FedSpace — which replans mid-run and is inherently sequential —
still pays per-run dispatch. Sweep rows report protocol-level
degradation (idle share, update counts, staleness); accuracy shows `—`
because the batched fast loop does not train models.

    PYTHONPATH=src python examples/fault_study.py
"""
import time

from repro.core.faults import random_churn, station_blackout
from repro.fl.api import (ConstellationConfig, DatasetConfig, FaultConfig,
                          FLExperiment, Federation, ISLConfig, LinkConfig,
                          SchedulerConfig)
from repro.fl.engine import EngineConfig
from repro.fl.sweep import run_sweep

K, G, WINDOWS = 40, 12, 192          # starlink40 over dense12, 2 days

SWEEPABLE = [
    SchedulerConfig("sync"),
    SchedulerConfig("fedbuff", params={"M": 10}),
    SchedulerConfig("intra_plane", params={"M": 10}),
]
FEDSPACE = SchedulerConfig(
    "fedspace",
    params={"I0": 24, "n_min": 4, "n_max": 8, "num_candidates": 512},
    setup={"pretrain_rounds": 10, "clients_per_round": 12,
           "utility_samples": 60, "local_steps": 8, "client_lr": 1.0})

SCENARIOS = [
    ("clean", FaultConfig()),
    ("churn20", FaultConfig(deorbit=random_churn(K, WINDOWS, 0.20, seed=0))),
    ("churn40", FaultConfig(deorbit=random_churn(K, WINDOWS, 0.40, seed=0))),
    ("blackout", FaultConfig(outages=station_blackout(G, 64, 128))),
    ("weather", FaultConfig(rate_scale_min=0.25, rate_scale_max=1.0,
                            seed=1)),
]


def _row(scenario, res, note=""):
    idle = 100.0 * res.idle_connections / max(res.total_connections, 1)
    hist = res.staleness_hist
    n_agg = max(int(hist.sum()), 1)
    stale = sum(s * int(n) for s, n in enumerate(hist)) / n_agg
    final = f"{res.accuracy[-1]:6.3f}" if len(res.accuracy) else f"{'—':>6s}"
    return (f"{scenario:9s} {res.scheme:12s} {idle:6.1f} "
            f"{res.num_global_updates:4d} "
            f"{res.num_aggregated_gradients:6d} {stale:6.2f} "
            f"{final}{note}")


def main():
    base = FLExperiment(
        name="fault_study",
        constellation=ConstellationConfig(preset="starlink40",
                                          ground="dense12", days=2.0),
        dataset=DatasetConfig(num_train=4000, num_val=800, noise=2.2),
        scheduler=SchedulerConfig(kind="fedbuff", params={"M": 10}),
        train=EngineConfig(local_steps=8, client_lr=1.0, eval_every=48,
                           max_windows=WINDOWS),
        link=LinkConfig(uplink_mbps=20.0, downlink_mbps=100.0,
                        model_mb=600.0, gs_capacity=2),
        isl=ISLConfig(isl_mbps=100.0, model_mb=600.0, epoch=24),
    )
    clean = Federation.from_experiment(base)
    worlds = {name: clean.with_faults(faults) for name, faults in SCENARIOS}

    # every sweepable (scenario x policy) cell in ONE batched dispatch
    cells = [(name, cfg) for name, _ in SCENARIOS for cfg in SWEEPABLE]
    t0 = time.time()
    results = run_sweep(
        [worlds[name].with_scheduler(cfg) for name, cfg in cells])
    swept = {(name, cfg.kind): res
             for (name, cfg), res in zip(cells, results)}
    t_sweep = time.time() - t0
    print(f"# {len(cells)} sweepable cells in one batched dispatch "
          f"({t_sweep:.0f}s); fedspace replans mid-run and stays "
          f"sequential\n")

    print(f"{'scenario':9s} {'scheme':12s} {'idle%':>6s} {'upd':>4s} "
          f"{'grads':>6s} {'stale':>6s} {'final':>6s}")
    for scenario, _ in SCENARIOS:
        for cfg in SWEEPABLE[:2]:
            print(_row(scenario, swept[(scenario, cfg.kind)]))
        t0 = time.time()
        res = worlds[scenario].with_scheduler(FEDSPACE).run()
        print(f"{_row(scenario, res)}  ({time.time() - t0:.0f}s)")
        print(_row(scenario, swept[(scenario, SWEEPABLE[2].kind)]))

    # what would perfect fault knowledge buy? FedSpace re-planned against
    # the *faulted* connectivity (oracle) vs the clean plan above (blind)
    print("\nfedspace under churn40, blind vs oracle planning:")
    for label, oracle in (("blind", False), ("oracle", True)):
        faults = FaultConfig(
            deorbit=random_churn(K, WINDOWS, 0.40, seed=0), oracle=oracle)
        t0 = time.time()
        res = clean.with_faults(faults).with_scheduler(FEDSPACE).run()
        print(f"{_row(label, res)}  ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
