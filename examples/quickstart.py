"""Quickstart: FedSpace in ~60 seconds on CPU, via the declarative API.

Declares one `FLExperiment` — a small 40-satellite constellation, a
synthetic fMoW-like dataset partitioned non-IID by ground track, the MLP
adapter — builds it once with `Federation.from_experiment`, then swaps
aggregation policies with `with_scheduler` to race FedSpace against
FedBuff, printing time-to-target for both.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import connectivity as CN
from repro.fl.api import (AdapterConfig, ConstellationConfig, DatasetConfig,
                          FLExperiment, Federation, PartitionConfig,
                          SchedulerConfig)
from repro.fl.engine import EngineConfig


def main():
    t0 = time.time()
    exp = FLExperiment(
        name="quickstart",
        constellation=ConstellationConfig(num_satellites=40, days=3.0),
        dataset=DatasetConfig(num_train=4000, num_val=1000, noise=2.2),
        partition=PartitionConfig(kind="noniid"),
        adapter=AdapterConfig(kind="mlp", params={"hidden": 48}),
        scheduler=SchedulerConfig(kind="fedbuff", params={"M": 20}),
        train=EngineConfig(local_steps=16, client_lr=1.0, eval_every=12,
                           target_acc=0.35, max_windows=288),
    )

    print("1. building the federation (constellation, data, adapter)...")
    fed = Federation.from_experiment(exp)
    st = CN.connectivity_stats(fed.C)
    print(f"   |C_i| in [{st['ci_min']}, {st['ci_max']}], "
          f"contacts/day in [{st['nk_min']:.0f}, {st['nk_max']:.0f}]")

    print("2. schedulers over the constellation (target 35% top-1)...")
    feds = [fed, fed.with_scheduler(SchedulerConfig(
        kind="fedspace",
        params={"I0": 24, "n_min": 4, "n_max": 8, "num_candidates": 500},
        setup={"pretrain_rounds": 25, "clients_per_round": 16,
               "utility_samples": 120, "local_steps": 16,
               "client_lr": 1.0}))]
    if feds[1].scheduler_diag:
        d = feds[1].scheduler_diag
        print(f"   fedspace phase 1: regressor R^2="
              f"{d['r2_in_sample']:.2f} on {d['n']} (s, T) -> dF samples")
    for f in feds:
        res = f.run()
        d = res.time_to_target_days
        print(f"   {res.scheme:9s} days_to_35%={d if d else 'not reached'} "
              f"updates={res.num_global_updates} "
              f"idle={res.idle_connections}/{res.total_connections} "
              f"staleness_hist={res.staleness_hist.tolist()}")
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
