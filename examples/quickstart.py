"""Quickstart: FedSpace in ~60 seconds on CPU.

Builds a small 40-satellite constellation, partitions a synthetic fMoW-like
dataset non-IID by ground track, trains the utility regressor, and runs the
FedSpace scheduler against FedBuff — printing time-to-target for both.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import connectivity as CN
from repro.core.scheduler import make_scheduler
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import noniid_partition
from repro.data.pipeline import make_clients
from repro.fl import fedspace_setup as FS
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.simulation import run_simulation


def main():
    t0 = time.time()
    print("1. deterministic constellation (40 satellites, 12 GS)...")
    spec = CN.ConstellationSpec(num_satellites=40)
    C = CN.connectivity_sets(spec, days=3.0)
    st = CN.connectivity_stats(C)
    print(f"   |C_i| in [{st['ci_min']}, {st['ci_max']}], "
          f"contacts/day in [{st['nk_min']:.0f}, {st['nk_max']:.0f}]")

    print("2. synthetic fMoW, non-IID by ground-track visits...")
    data = SyntheticFmow(FmowSpec(num_train=4000, num_val=1000, noise=2.2))
    parts = noniid_partition(data.train_zones, 40, spec, days=3.0)
    adapter = MlpFmowAdapter(data, make_clients(parts), hidden=48)

    print("3. FedSpace phase 1: source trajectory + utility regressor...")
    traj = FS.pretrain_trajectory(adapter, rounds=25, local_steps=16,
                                  client_lr=1.0)
    reg, diag = FS.fit_utility_regressor(adapter, traj, n_samples=120,
                                         local_steps=16, client_lr=1.0)
    print(f"   random-forest fit R^2={diag['r2_in_sample']:.2f} "
          f"on {diag['n']} (s, T) -> dF samples")

    print("4. schedulers over the constellation (target 35% top-1)...")
    for name, sched in [
        ("fedbuff", make_scheduler("fedbuff", M=20)),
        ("fedspace", make_scheduler("fedspace", regressor=reg, I0=24,
                                    n_min=4, n_max=8,
                                    num_candidates=500)),
    ]:
        res = run_simulation(C, adapter, sched, client_lr=1.0,
                             local_steps=16, eval_every=12,
                             target_acc=0.35, max_windows=288)
        d = res.time_to_target_days
        print(f"   {name:9s} days_to_35%={d if d else 'not reached'} "
              f"updates={res.num_global_updates} "
              f"idle={res.idle_connections}/{res.total_connections} "
              f"staleness_hist={res.staleness_hist.tolist()}")
    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
