"""Scheduler comparison (paper Figs. 6-7 in miniature): run all four
schedulers (+ the beyond-paper periodic baseline) on one 64-satellite world
and print the accuracy-vs-days table and the staleness/idleness profile.

    PYTHONPATH=src python examples/scheduler_comparison.py
"""
import time

import numpy as np

from repro.core import connectivity as CN
from repro.core.scheduler import make_scheduler
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import noniid_partition
from repro.data.pipeline import make_clients
from repro.fl import fedspace_setup as FS
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.simulation import run_simulation


def main():
    K = 64
    spec = CN.ConstellationSpec(num_satellites=K)
    C = CN.connectivity_sets(spec, days=4.0)
    data = SyntheticFmow(FmowSpec(num_train=6000, num_val=1200, noise=2.2))
    parts = noniid_partition(data.train_zones, K, spec, days=4.0)
    adapter = MlpFmowAdapter(data, make_clients(parts), hidden=48)

    traj = FS.pretrain_trajectory(adapter, rounds=30, local_steps=16,
                                  client_lr=1.0)
    reg, _ = FS.fit_utility_regressor(adapter, traj, n_samples=150,
                                      local_steps=16, client_lr=1.0)
    scheds = [
        ("sync", make_scheduler("sync")),
        ("async", make_scheduler("async")),
        ("fedbuff", make_scheduler("fedbuff", M=32)),
        ("periodic", make_scheduler("periodic", period=4)),
        ("fedspace", make_scheduler("fedspace", regressor=reg, I0=24,
                                    n_min=4, n_max=8, num_candidates=800)),
    ]
    print(f"{'scheme':10s} {'final':>6s} {'best':>6s} {'upd':>5s} "
          f"{'idle':>10s}  staleness histogram (0..8+)")
    for name, sched in scheds:
        t0 = time.time()
        res = run_simulation(C, adapter, sched, client_lr=1.0,
                             local_steps=16, eval_every=24,
                             max_windows=384)
        print(f"{name:10s} {res.accuracy[-1]:6.3f} "
              f"{max(res.accuracy):6.3f} {res.num_global_updates:5d} "
              f"{res.idle_connections:4d}/{res.total_connections:5d}  "
              f"{res.staleness_hist.tolist()}  ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
