"""Scheduler comparison (paper Figs. 6-7 in miniature): one declarative
preset world, every registered policy raced over it via
`Federation.with_scheduler` — constellation, data, adapter, and the ISL
topology built once and shared across all runs. The experiment carries an
`ISLConfig`, which only the ISL-aware policies (`intra_plane`,
`isl_async`) act on — the ground-only schedulers run the unmodified
protocol on the very same world, so the comparison is apples-to-apples.

    PYTHONPATH=src python examples/scheduler_comparison.py
"""
import time

from repro.fl.api import (AdapterConfig, ConstellationConfig, DatasetConfig,
                          FLExperiment, Federation, ISLConfig,
                          PartitionConfig, SchedulerConfig)
from repro.fl.engine import EngineConfig


def main():
    exp = FLExperiment(
        name="scheduler_comparison",
        constellation=ConstellationConfig(preset="starlink40", days=4.0),
        dataset=DatasetConfig(num_train=6000, num_val=1200, noise=2.2),
        partition=PartitionConfig(kind="noniid"),
        adapter=AdapterConfig(kind="mlp", params={"hidden": 48}),
        scheduler=SchedulerConfig(kind="sync"),
        train=EngineConfig(local_steps=16, client_lr=1.0, eval_every=24,
                           max_windows=384),
        isl=ISLConfig(isl_mbps=100.0, model_mb=600.0, epoch=24),
    )
    base = Federation.from_experiment(exp)
    scheds = [
        SchedulerConfig("sync"),
        SchedulerConfig("async"),
        SchedulerConfig("fedbuff", params={"M": 20}),
        SchedulerConfig("periodic", params={"period": 4}),
        SchedulerConfig("intra_plane"),
        SchedulerConfig("isl_async"),
        SchedulerConfig("fedspace",
                        params={"I0": 24, "n_min": 4, "n_max": 8,
                                "num_candidates": 800},
                        setup={"pretrain_rounds": 30, "clients_per_round": 16,
                               "utility_samples": 150, "local_steps": 16,
                               "client_lr": 1.0}),
    ]
    # build every policy first (FedSpace phase 1 runs here) so the timed
    # loop below compares simulation time only
    feds = [base.with_scheduler(cfg) for cfg in scheds]
    print(f"{'scheme':12s} {'final':>6s} {'best':>6s} {'upd':>5s} "
          f"{'idle':>11s}  staleness histogram (0..8+)")
    for fed in feds:
        t0 = time.time()
        res = fed.run()
        print(f"{res.scheme:12s} {res.accuracy[-1]:6.3f} "
              f"{max(res.accuracy):6.3f} {res.num_global_updates:5d} "
              f"{res.idle_connections:5d}/{res.total_connections:5d}  "
              f"{res.staleness_hist.tolist()}  ({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
