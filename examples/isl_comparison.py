"""ISL scenario study: does letting satellites talk to each other help,
and when?

Races the two ISL policies — `intra_plane` (ring relay toward elected
sink satellites, arXiv 2302.13447) and `isl_async` (asynchronous gossip
over ring neighbours, arXiv 2206.00307) — against the ground-only
baselines (FedSpace-style fedbuff, sync) on the paper's flock191 mix and
the Starlink-like starlink40 preset, under the dense 12-station network
vs single-station Svalbard, with and without a finite link budget.

The interesting cell is sparse ground + finite budget: with one polar
station and capacity-limited contacts, most satellites idle between rare
passes. Sink relaying funnels whole planes through each plane's
best-placed contact; gossip spreads fresh global models through planes
the station never sees. Both experiments share ONE world per cell
(constellation, data, adapter, ISL topology) via
`Federation.with_scheduler`, so differences are pure policy.

    PYTHONPATH=src python examples/isl_comparison.py
"""
import dataclasses
import time

from repro.fl.api import (ConstellationConfig, DatasetConfig, FLExperiment,
                          Federation, ISLConfig, LinkConfig,
                          SchedulerConfig)
from repro.fl.engine import EngineConfig

SCHEDULERS = [
    SchedulerConfig("fedbuff", params={"M": 12}),
    SchedulerConfig("sync"),
    SchedulerConfig("intra_plane"),
    SchedulerConfig("isl_async"),
]


def main():
    base = FLExperiment(
        name="isl_comparison",
        dataset=DatasetConfig(num_train=4000, num_val=800, noise=2.2),
        scheduler=SchedulerConfig(kind="fedbuff", params={"M": 12}),
        train=EngineConfig(local_steps=8, client_lr=1.0, eval_every=48,
                           max_windows=192),
        # 600 MB model over 100 Mbit/s laser crosslinks: one ring hop per
        # window; sinks re-elected every 6 simulated hours
        isl=ISLConfig(isl_mbps=100.0, model_mb=600.0, epoch=24),
    )
    budget = LinkConfig(uplink_mbps=20.0, downlink_mbps=100.0,
                        model_mb=600.0, gs_capacity=1)

    print(f"{'preset':10s} {'ground':8s} {'links':7s} {'scheme':12s} "
          f"{'idle%':>6s} {'upd':>4s} {'grads':>6s} "
          f"{'final':>6s}")
    for preset in ("flock191", "starlink40"):
        for ground in ("dense12", "sparse1"):
            for label, link in (("free", LinkConfig()), ("budget", budget)):
                exp = dataclasses.replace(
                    base,
                    constellation=ConstellationConfig(
                        preset=preset, ground=ground, days=2.0),
                    link=link)
                world = Federation.from_experiment(exp)
                for cfg in SCHEDULERS:
                    t0 = time.time()
                    res = world.with_scheduler(cfg).run()
                    idle = (100.0 * res.idle_connections
                            / max(res.total_connections, 1))
                    print(f"{preset:10s} {ground:8s} {label:7s} "
                          f"{res.scheme:12s} {idle:6.1f} "
                          f"{res.num_global_updates:4d} "
                          f"{res.num_aggregated_gradients:6d} "
                          f"{res.accuracy[-1]:6.3f}  "
                          f"({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
