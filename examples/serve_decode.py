"""Persistent-jit serving loop: batched autoregressive decoding through
one jitted `serve_step` compiled once and reused for every token of every
request — the serving pattern this repo uses whenever a long-lived
process answers a stream of same-shaped requests (`examples/
serve_replan.py` builds the schedule-replanning service on the same idea;
docs/replanning.md documents the pattern).

Greedy-decodes a batch of prompts against reduced configs of three
architectures (gemma3 with 5:1 local:global attention, mamba2 with SSM
state, mixtral MoE). The decode state (KV cache / SSM state) stays on
device across calls; each step feeds one token per request, and because
every call sees identical shapes, the jit cache is hit from the second
token on.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


def serve(arch: str, batch: int = 4, prompt_len: int = 8,
          gen_tokens: int = 24, cache_len: int = 64):
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh()
    key = jax.random.PRNGKey(0)
    with mesh:
        params = T.init_params(key, cfg)
        serve_step = jax.jit(ST.make_serve_step(cfg))
        state = T.init_decode_state(params, cfg, batch, cache_len,
                                    jnp.float32)
        prompt = jax.random.randint(key, (batch, prompt_len), 0,
                                    cfg.vocab_size)
        # prefill by stepping the prompt (simple serving loop)
        tok = prompt[:, :1]
        t0 = time.time()
        for t in range(prompt_len - 1):
            _, state = serve_step(params, state, prompt[:, t:t + 1])
        generated = []
        tok = prompt[:, -1:]
        for _ in range(gen_tokens):
            tok, state = serve_step(params, state, tok)
            generated.append(tok)
        out = jnp.concatenate(generated, axis=1)
        dt = time.time() - t0
    total = batch * (prompt_len - 1 + gen_tokens)
    print(f"{arch:24s} batch={batch} generated {out.shape[1]} tokens/req; "
          f"{total / dt:.0f} tok/s on CPU; cache_index="
          f"{int(state['index'])}")
    return out


def main():
    for arch in ["gemma3-12b", "mamba2-370m", "mixtral-8x7b"]:
        serve(arch)


if __name__ == "__main__":
    main()
