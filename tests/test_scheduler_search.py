"""Scheduler and schedule-search tests: candidate generation bounds, search
improvement over random, regressor fitting, and scheduler indicator
semantics (eqs. 5-7)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import staleness as SS
from repro.core.scheduler import (AsyncScheduler, FedBuffScheduler,
                                  SyncScheduler)
from repro.core.search import random_candidates, score_candidates
from repro.core.utility import (MLPRegressor, RandomForestRegressor,
                                featurize)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 30), st.integers(0, 5), st.integers(5, 10),
       st.integers(1, 64))
def test_candidates_within_range(I0, nmin, nmax, R):
    rng = np.random.default_rng(0)
    c = random_candidates(rng, I0, nmin, nmax, R)
    assert c.shape == (R, I0)
    counts = c.sum(axis=1)
    assert (counts >= min(nmin, I0)).all()
    assert (counts <= min(nmax, I0)).all()


def test_indicators():
    assert SyncScheduler().decide(0, n_in_buffer=5, K=5)
    assert not SyncScheduler().decide(0, n_in_buffer=4, K=5)
    assert AsyncScheduler().decide(0, n_in_buffer=1)
    assert not AsyncScheduler().decide(0, n_in_buffer=0)
    fb = FedBuffScheduler(M=3)
    assert fb.decide(0, n_in_buffer=3) and not fb.decide(0, n_in_buffer=2)


def test_regressors_fit_quadratic():
    rng = np.random.default_rng(1)
    X = rng.random((400, 6)).astype(np.float32)
    y = (X[:, 0] - 0.5) ** 2 * 4 + X[:, 3]
    for reg in (RandomForestRegressor(n_trees=20, max_depth=6, seed=1),
                MLPRegressor(steps=600, seed=1)):
        reg.fit(X, y)
        pred = reg.predict(X)
        r2 = 1 - np.sum((pred - y) ** 2) / np.sum((y - y.mean()) ** 2)
        assert r2 > 0.7, type(reg).__name__


def test_featurize_shapes():
    # hist (s_max+1=9) + total + fresh_mass + mean_stale + status = 13
    f = featurize(np.zeros((5, 9)), 1.5)
    assert f.shape == (5, 13)
    assert (f[:, -1] == 1.5).all()
    # derived features: fresh-weighted mass respects c(s) decay
    h = np.zeros(9); h[0] = 2; h[3] = 2
    f2 = featurize(h[None], 0.0)[0]
    assert f2[9] == 4.0                       # total
    assert 2.0 < f2[10] < 4.0                 # fresh mass in (c(3)*4, 4)
    assert abs(f2[11] - 1.5) < 1e-6           # mean staleness


class _FreshGradientOracle:
    """True utility: fresh gradients help, stale ones hurt."""

    def predict(self, X):
        hist = X[:, :-2]
        s = np.arange(hist.shape[1])
        return (hist * (1.0 - 0.4 * s)).sum(axis=1)


def test_search_beats_random_average():
    rng = np.random.default_rng(2)
    K, I0 = 30, 24
    C = rng.random((I0, K)) < 0.25
    state = SS.bootstrap_state(K)
    cands = random_candidates(rng, I0, 4, 8, 256)
    scores = score_candidates(cands, C, state, 0, _FreshGradientOracle(),
                              status=1.0)
    assert scores.max() > np.mean(scores) + 1e-6
