"""Parity tests for the vectorized simulation hot paths.

Strict-parity contract of the vectorization PRs:
  * the structure-of-arrays numpy forest predict bit-matches the per-row
    node-walk reference;
  * the jit/JAX forest predict and featurize match to XLA reduction-order
    tolerance, and the end-to-end `fedspace_search` still selects the
    identical schedule;
  * the device-resident engine (chunked jitted window scans, device
    SatState, checkpoint ring, batched `on_aggregate`) reproduces the seed
    host-loop engine's trajectory bit-identically — and its own per-window
    host fallback exactly — including under the FedSpace scheduler's
    re-planning;
  * `aggregate_params_tree` agrees between the Pallas interpreter and the
    jnp tensordot oracle, and the default off-TPU dispatch is bit-identical
    to the oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity as CN
from repro.core import staleness as SS
from repro.core.search import fedspace_search, infer_n_range
from repro.core.utility import (RandomForestRegressor, featurize,
                                featurize_jnp)
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition
from repro.data.pipeline import make_clients
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.compression import roundtrip
from repro.fl.engine import EngineConfig, SimulationEngine
from repro.core.scheduler import make_scheduler
from repro.kernels import on_tpu
from repro.kernels.agg.ops import aggregate_params_tree


def _fit_forest(seed, *, n_trees=15, max_depth=5, n=300, F=13):
    rng = np.random.default_rng(seed)
    X = rng.random((n, F)).astype(np.float32)
    y = (2 * X[:, 0] + np.sin(6 * X[:, 3])
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    rf = RandomForestRegressor(n_trees=n_trees, max_depth=max_depth,
                               seed=seed).fit(X, y)
    return rf, rng


def _fit_hist_forest(seed, *, s_max=8, n=400):
    """Forest over the search feature space (staleness histograms)."""
    rng = np.random.default_rng(seed)
    hists = rng.integers(0, 25, (n, s_max + 1)).astype(np.float32)
    X = featurize(hists, 1.0)
    s = np.arange(s_max + 1, dtype=np.float32)
    y = ((hists * (1.2 - 0.3 * s)).sum(1)
         / np.maximum(hists.sum(1), 1.0)
         + 0.05 * rng.normal(size=n)).astype(np.float32)
    return RandomForestRegressor(n_trees=20, max_depth=6, seed=seed
                                 ).fit(X, y)


class _NodeWalkHost:
    """Seed-style regressor facade: pure-Python node walk, host featurize
    (no predict_device => score_candidates takes the host path)."""

    def __init__(self, rf):
        self._rf = rf

    def predict(self, X):
        return self._rf.predict_reference(X)


# ---------------------------------------------------------------------------
# forest inference


@pytest.mark.parametrize("seed,depth,trees", [(0, 5, 15), (1, 6, 30),
                                              (2, 2, 5), (3, 8, 10)])
def test_soa_predict_bitmatches_node_walk(seed, depth, trees):
    rf, rng = _fit_forest(seed, n_trees=trees, max_depth=depth)
    X = rng.random((500, 13)).astype(np.float32)
    ref = rf.predict_reference(X)
    fast = rf.predict(X)
    assert np.array_equal(ref, fast)


def test_device_predict_matches_node_walk():
    rf, rng = _fit_forest(0)
    X = rng.random((500, 13)).astype(np.float32)
    ref = rf.predict_reference(X)
    dev = np.asarray(rf.predict_device(jnp.asarray(X)))
    np.testing.assert_allclose(dev, ref, rtol=1e-5, atol=1e-6)


def test_featurize_jnp_matches_host():
    rng = np.random.default_rng(0)
    hist = rng.integers(0, 30, (128, 9)).astype(np.float32)
    host = featurize(hist, 0.7)
    dev = np.asarray(featurize_jnp(jnp.asarray(hist), 0.7))
    assert dev.shape == host.shape
    np.testing.assert_allclose(dev, host, rtol=1e-6, atol=1e-5)
    # integer-exact features are bit-exact
    assert np.array_equal(dev[:, :9], host[:, :9])       # raw histogram
    assert np.array_equal(dev[:, 9], host[:, 9])         # total count


def test_fedspace_search_selects_identical_schedule():
    """The acceptance gate: same rng seed => same selected schedule on the
    device path as on the seed node-walk/host path."""
    rf = _fit_hist_forest(0)
    rng = np.random.default_rng(5)
    K, I0 = 24, 24
    C = rng.random((I0, K)) < 0.2
    state = SS.bootstrap_state(K)
    ref = fedspace_search(np.random.default_rng(7), C, state, 0,
                          _NodeWalkHost(rf), 1.0, num_candidates=512)
    opt = fedspace_search(np.random.default_rng(7), C, state, 0, rf, 1.0,
                          num_candidates=512)
    assert np.array_equal(ref, opt)


def test_infer_n_range_matches_loop_reference():
    rf = _fit_hist_forest(1)

    def reference(regressor, uploads_per_window, I0, status, *, s_max=8,
                  K=None, halfwidth=4):
        best_n, best_u = 1, -np.inf
        n_cap = max(1, I0 // 2)
        total = uploads_per_window * I0
        for n in range(1, n_cap + 1):
            per = total / n
            if K:
                per = min(per, K)
            hist = np.zeros(s_max + 1, np.float32)
            hist[0] = per * 0.7
            hist[1] = per * 0.3
            u = n * float(regressor.predict(featurize(hist[None],
                                                      status))[0])
            if u > best_u:
                best_n, best_u = n, u
        return max(1, best_n - halfwidth), min(n_cap, best_n + halfwidth)

    rng = np.random.default_rng(2)
    upws = [0.5, 2.0, 5.0, 11.0] + list(rng.uniform(0.1, 20.0, 40))
    for upw in upws:
        for K in (None, 16):
            assert infer_n_range(rf, upw, 24, 1.0, K=K) \
                == reference(rf, upw, 24, 1.0, K=K), (upw, K)


# ---------------------------------------------------------------------------
# batched aggregation round


class _SeedHostEngine:
    """The pre-refactor engine, transcribed as the parity oracle: numpy
    protocol arrays rebuilt into a SatState every window, a host-pytree
    CheckpointStore, one jitted client update + checkpoint fetch per
    buffered satellite, sequential compression roundtrip, and a
    stack-tensordot-add aggregation."""

    def __init__(self, C, adapter, scheduler, config):
        self.config = dataclasses.replace(
            config, seed=0 if config.seed is None else config.seed,
            uplink_topk=(0.0 if config.uplink_topk is None
                         else config.uplink_topk))
        self.C = np.asarray(C, bool)
        self.adapter = adapter
        self.scheduler = scheduler
        self.num_windows = self.C.shape[0]
        if self.config.max_windows:
            self.num_windows = min(self.num_windows,
                                   self.config.max_windows)
        self.K = self.C.shape[1]

    def run(self):
        from repro.ckpt.checkpoint import CheckpointStore
        from repro.core.staleness import staleness_compensation
        from repro.fl.client import make_client_update
        from repro.fl.engine import SimResult
        cfg = self.config
        self.scheduler.reset()
        params = self.adapter.init(jax.random.PRNGKey(cfg.seed))
        mask = self.adapter.trainable_mask(params) \
            if hasattr(self.adapter, "trainable_mask") else None
        client_update = make_client_update(
            self.adapter, local_steps=cfg.local_steps, lr=cfg.client_lr,
            trainable_mask=mask)
        store = CheckpointStore(keep_in_memory=cfg.s_max + 26)
        store.put(0, params)
        ig = 0
        version = np.zeros(self.K, np.int64)
        pending = np.zeros(self.K, np.int64)
        buffered = np.full(self.K, -1, np.int64)
        res = SimResult(scheme=self.scheduler.name,
                        target_acc=cfg.target_acc)
        res.staleness_hist = np.zeros(cfg.s_max + 1, np.int64)
        status = float(self.adapter.val_loss(params))
        for i in range(self.num_windows):
            conn = self.C[i]
            res.total_connections += int(conn.sum())
            has_pending = conn & (pending >= 0)
            res.idle_connections += int(
                (conn & ~has_pending & (version == ig)).sum())
            buffered[has_pending] = pending[has_pending]
            pending[has_pending] = -1
            n_buf = int((buffered >= 0).sum())
            state = SS.SatState(jnp.asarray(version, jnp.int32),
                                jnp.asarray(pending, jnp.int32),
                                jnp.asarray(buffered, jnp.int32))
            a = self.scheduler.decide(
                i, n_in_buffer=n_buf, K=self.K, state=state, ig=ig,
                connectivity=self.C, status=status)
            if a and n_buf > 0:
                ks = np.flatnonzero(buffered >= 0)
                stal = ig - buffered[ks]
                updates = []
                for k in ks:
                    base = store.get(int(buffered[k]))
                    u = client_update(base, int(k), round_rng=i,
                                      batch_size=cfg.batch_size)
                    if cfg.uplink_topk > 0.0:
                        u, _ = roundtrip(u, cfg.uplink_topk)
                    updates.append(u)
                stack = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
                c = staleness_compensation(jnp.asarray(stal), cfg.alpha)
                wv = c / jnp.maximum(jnp.sum(c), 1e-12) * cfg.server_lr
                delta = jax.tree.map(
                    lambda u_: jnp.tensordot(wv.astype(jnp.float32),
                                             u_.astype(jnp.float32),
                                             axes=1), stack)
                params = jax.tree.map(
                    lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
                    params, delta)
                ig += 1
                store.put(ig, params)
                refs = np.concatenate([pending, buffered])
                refs = refs[refs >= 0]
                store.prune(int(refs.min()) if refs.size else ig)
                res.num_global_updates += 1
                res.num_aggregated_gradients += len(ks)
                np.add.at(res.staleness_hist, np.clip(stal, 0, cfg.s_max), 1)
                buffered[:] = -1
            behind = conn & (version < ig)
            version[behind] = ig
            pending[behind] = ig
            res.windows_run = i + 1
            stop = False
            if (i + 1) % cfg.eval_every == 0 or i == self.num_windows - 1:
                acc = self.adapter.accuracy(params)
                status = float(self.adapter.val_loss(params))
                res.accuracy.append(acc)
                res.val_loss.append(status)
                res.eval_windows.append(i)
                if (cfg.target_acc is not None and acc >= cfg.target_acc
                        and res.time_to_target_days is None):
                    res.time_to_target_days = res.days(i)
                    if cfg.stop_at_target:
                        stop = True
            if stop:
                break
        self.params = params
        return res


@pytest.fixture(scope="module")
def tiny_world():
    spec = CN.ConstellationSpec(num_satellites=16)
    C = CN.connectivity_sets(spec, days=1.0)
    data = SyntheticFmow(FmowSpec(num_train=800, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(iid_partition(800, 16, 0)))
    return C, adapter


def test_batched_aggregate_bit_identical_trajectory(tiny_world):
    C, adapter = tiny_world
    cfg = dict(eval_every=16, max_windows=64)
    ref_eng = _SeedHostEngine(C, adapter, make_scheduler("fedbuff", M=4),
                              EngineConfig(**cfg))
    ref = ref_eng.run()
    new_eng = SimulationEngine(C, adapter, make_scheduler("fedbuff", M=4),
                               EngineConfig(**cfg))
    new = new_eng.run()
    assert new.summary() == ref.summary()
    assert new.accuracy == ref.accuracy
    assert new.val_loss == ref.val_loss
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        new_eng.params, ref_eng.params)


def test_batched_aggregate_with_fused_compression(tiny_world):
    """Compressed-uplink mode: the fused in-jit roundtrip matches the
    sequential eager one to ~1 ulp (XLA strength-reduces the /127 dequant
    constant inside the fused program), so the trajectory agrees to float
    noise; all integer protocol counters are exact."""
    C, adapter = tiny_world
    cfg = dict(eval_every=16, max_windows=64, uplink_topk=0.25)
    ref_eng = _SeedHostEngine(C, adapter, make_scheduler("fedbuff", M=4),
                              EngineConfig(**cfg))
    ref = ref_eng.run()
    new_eng = SimulationEngine(C, adapter, make_scheduler("fedbuff", M=4),
                               EngineConfig(**cfg))
    new = new_eng.run()
    assert new.num_global_updates == ref.num_global_updates
    assert new.num_aggregated_gradients == ref.num_aggregated_gradients
    assert new.staleness_hist.tolist() == ref.staleness_hist.tolist()
    assert new.windows_run == ref.windows_run
    np.testing.assert_allclose(new.val_loss, ref.val_loss, atol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b), atol=1e-7),
        new_eng.params, ref_eng.params)


def test_batched_aggregate_handles_empty_shards():
    """Satellites with empty shards contribute exact-zero updates, batched
    alongside trained ones."""
    K = 8
    rng = np.random.default_rng(0)
    C = rng.random((32, K)) < 0.4
    data = SyntheticFmow(FmowSpec(num_train=200, num_val=50))
    parts = iid_partition(200, K - 2, 0) + [np.array([], np.int64)] * 2
    adapter = MlpFmowAdapter(data, make_clients(parts))
    cfg = dict(eval_every=16, max_windows=32)
    ref = _SeedHostEngine(C, adapter, make_scheduler("async"),
                          EngineConfig(**cfg)).run()
    new = SimulationEngine(C, adapter, make_scheduler("async"),
                           EngineConfig(**cfg)).run()
    assert new.summary() == ref.summary()
    assert new.accuracy == ref.accuracy


# ---------------------------------------------------------------------------
# chunked fast loop vs per-window host loop


@pytest.mark.parametrize("scheme,kw", [("async", {}), ("fedbuff", {"M": 4}),
                                       ("periodic", {"period": 3})])
def test_fast_loop_matches_host_loop(tiny_world, scheme, kw):
    """The engine's two execution strategies — chunked jitted scans vs
    per-window protocol-step calls — must produce identical results and
    bit-identical parameters."""
    C, adapter = tiny_world
    cfg = dict(eval_every=16, max_windows=64)
    fast_eng = SimulationEngine(C, adapter, make_scheduler(scheme, **kw),
                                EngineConfig(**cfg))
    fast = fast_eng.run()
    assert fast_eng._fast_ok            # took the chunked path
    host_eng = SimulationEngine(C, adapter, make_scheduler(scheme, **kw),
                                EngineConfig(fast_loop=False, **cfg))
    host = host_eng.run()
    assert not host_eng._fast_ok
    assert fast.summary() == host.summary()
    assert fast.accuracy == host.accuracy
    np.testing.assert_array_equal(fast_eng.version, host_eng.version)
    np.testing.assert_array_equal(fast_eng.pending, host_eng.pending)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        fast_eng.params, host_eng.params)


def test_fedspace_fast_loop_matches_host_loop(tiny_world):
    """FedSpace re-plans every I0 windows from the live protocol state;
    the chunked loop must hand `fedspace_search` the identical post-upload
    state (and consume the scheduler rng identically), so the schedules —
    and hence the whole trajectory — match the per-window loop exactly."""
    from repro.core.scheduler import FedSpaceScheduler
    C, adapter = tiny_world
    rf = _fit_hist_forest(3)
    cfg = dict(eval_every=8, max_windows=48)
    fast_eng = SimulationEngine(
        C, adapter,
        FedSpaceScheduler(rf, I0=8, num_candidates=64, seed=11),
        EngineConfig(**cfg))
    fast = fast_eng.run()
    assert fast_eng._fast_ok
    host_eng = SimulationEngine(
        C, adapter,
        FedSpaceScheduler(rf, I0=8, num_candidates=64, seed=11),
        EngineConfig(fast_loop=False, **cfg))
    host = host_eng.run()
    assert fast.summary() == host.summary()
    assert fast.accuracy == host.accuracy
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        fast_eng.params, host_eng.params)


def test_fast_loop_respects_early_stop_and_target(tiny_world):
    """Chunk boundaries align with eval windows, so target-accuracy stops
    fire at the same window on both strategies."""
    C, adapter = tiny_world
    cfg = dict(eval_every=8, max_windows=96, target_acc=0.1)
    fast = SimulationEngine(C, adapter, make_scheduler("async"),
                            EngineConfig(**cfg)).run()
    host = SimulationEngine(C, adapter, make_scheduler("async"),
                            EngineConfig(fast_loop=False, **cfg)).run()
    assert fast.windows_run == host.windows_run
    assert fast.time_to_target_days == host.time_to_target_days


# ---------------------------------------------------------------------------
# vectorized utility-sample generation (eq. 12)


def test_vectorized_utility_samples_match_loop(tiny_world):
    """The batched sample generator (grouped vmapped client training +
    vmapped loss over perturbed checkpoints) shares the loop path's rng
    stream: features — integer staleness histograms + T — are
    bit-identical, targets agree to reduction-order tolerance."""
    from repro.core.utility import generate_utility_samples
    from repro.fl.client import (make_batched_client_update,
                                 make_client_update)
    from repro.fl.fedspace_setup import pretrain_trajectory
    _, adapter = tiny_world
    traj = pretrain_trajectory(adapter, rounds=6, clients_per_round=6,
                               local_steps=2, client_lr=0.3, seed=0)
    cu = make_client_update(adapter, local_steps=2, lr=0.3)

    def upd_fn(base, ci, r):
        return cu(base, ci, round_rng=int(r))

    common = dict(num_clients=16, n_samples=24, s_max=8,
                  clients_per_sample=8, seed=5)
    X_loop, y_loop = generate_utility_samples(
        jax.random.PRNGKey(0), traj, upd_fn,
        lambda p: adapter.val_loss(p), **common)
    val_batch = adapter.eval_batch()
    X_vec, y_vec = generate_utility_samples(
        jax.random.PRNGKey(0), traj, upd_fn,
        lambda p: adapter.val_loss(p),
        batch_fn=lambda ci, r: adapter.client_batch(ci, int(r), 32, 2),
        batched_update_fn=make_batched_client_update(
            adapter, local_steps=2, lr=0.3),
        batched_loss_fn=jax.jit(jax.vmap(
            lambda p: adapter.loss(p, val_batch))),
        **common)
    assert np.array_equal(X_loop, X_vec)
    np.testing.assert_allclose(y_vec, y_loop, atol=1e-5)


# ---------------------------------------------------------------------------
# aggregation kernel routing


def _rand_tree(rng, M):
    params = {"w": jnp.asarray(rng.normal(size=(17, 23)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(11,)).astype(np.float32))}
    upds = jax.tree.map(
        lambda p: jnp.asarray(
            rng.normal(size=(M,) + p.shape).astype(np.float32)), params)
    w = jnp.asarray(rng.random(M).astype(np.float32))
    return params, upds, w


def test_aggregate_params_tree_interpret_matches_tensordot():
    rng = np.random.default_rng(3)
    params, upds, w = _rand_tree(rng, 6)
    ref = jax.tree.map(
        lambda p, u: p + jnp.tensordot(w, u.astype(jnp.float32), axes=1),
        params, upds)
    interp = aggregate_params_tree(params, upds, w, interpret=True)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5), interp, ref)


@pytest.mark.skipif(on_tpu(), reason="off-TPU dispatch contract")
def test_aggregate_params_tree_default_bitmatches_tensordot_off_tpu():
    """The engine's default dispatch must stay bit-identical to the eager
    tensordot reduction the seed engine used."""
    rng = np.random.default_rng(4)
    params, upds, w = _rand_tree(rng, 9)
    ref = jax.tree.map(
        lambda p, u: p + jnp.tensordot(w, u.astype(jnp.float32), axes=1),
        params, upds)
    out = aggregate_params_tree(params, upds, w)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), out, ref)
