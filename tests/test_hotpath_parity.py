"""Parity tests for the vectorized simulation hot paths.

Strict-parity contract of the vectorization PR:
  * the structure-of-arrays numpy forest predict bit-matches the per-row
    node-walk reference;
  * the jit/JAX forest predict and featurize match to XLA reduction-order
    tolerance, and the end-to-end `fedspace_search` still selects the
    identical schedule;
  * the batched `on_aggregate` (grouped vmapped client training, fused
    top-k compression, kernel-routed reduction) reproduces the seed
    engine's per-satellite-loop trajectory bit-identically;
  * `aggregate_params_tree` agrees between the Pallas interpreter and the
    jnp tensordot oracle, and the default off-TPU dispatch is bit-identical
    to the oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity as CN
from repro.core import staleness as SS
from repro.core.search import fedspace_search, infer_n_range
from repro.core.utility import (RandomForestRegressor, featurize,
                                featurize_jnp)
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition
from repro.data.pipeline import make_clients
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.compression import roundtrip
from repro.fl.engine import EngineConfig, SimulationEngine
from repro.core.scheduler import make_scheduler
from repro.kernels import on_tpu
from repro.kernels.agg.ops import aggregate_params_tree


def _fit_forest(seed, *, n_trees=15, max_depth=5, n=300, F=13):
    rng = np.random.default_rng(seed)
    X = rng.random((n, F)).astype(np.float32)
    y = (2 * X[:, 0] + np.sin(6 * X[:, 3])
         + 0.1 * rng.normal(size=n)).astype(np.float32)
    rf = RandomForestRegressor(n_trees=n_trees, max_depth=max_depth,
                               seed=seed).fit(X, y)
    return rf, rng


def _fit_hist_forest(seed, *, s_max=8, n=400):
    """Forest over the search feature space (staleness histograms)."""
    rng = np.random.default_rng(seed)
    hists = rng.integers(0, 25, (n, s_max + 1)).astype(np.float32)
    X = featurize(hists, 1.0)
    s = np.arange(s_max + 1, dtype=np.float32)
    y = ((hists * (1.2 - 0.3 * s)).sum(1)
         / np.maximum(hists.sum(1), 1.0)
         + 0.05 * rng.normal(size=n)).astype(np.float32)
    return RandomForestRegressor(n_trees=20, max_depth=6, seed=seed
                                 ).fit(X, y)


class _NodeWalkHost:
    """Seed-style regressor facade: pure-Python node walk, host featurize
    (no predict_device => score_candidates takes the host path)."""

    def __init__(self, rf):
        self._rf = rf

    def predict(self, X):
        return self._rf.predict_reference(X)


# ---------------------------------------------------------------------------
# forest inference


@pytest.mark.parametrize("seed,depth,trees", [(0, 5, 15), (1, 6, 30),
                                              (2, 2, 5), (3, 8, 10)])
def test_soa_predict_bitmatches_node_walk(seed, depth, trees):
    rf, rng = _fit_forest(seed, n_trees=trees, max_depth=depth)
    X = rng.random((500, 13)).astype(np.float32)
    ref = rf.predict_reference(X)
    fast = rf.predict(X)
    assert np.array_equal(ref, fast)


def test_device_predict_matches_node_walk():
    rf, rng = _fit_forest(0)
    X = rng.random((500, 13)).astype(np.float32)
    ref = rf.predict_reference(X)
    dev = np.asarray(rf.predict_device(jnp.asarray(X)))
    np.testing.assert_allclose(dev, ref, rtol=1e-5, atol=1e-6)


def test_featurize_jnp_matches_host():
    rng = np.random.default_rng(0)
    hist = rng.integers(0, 30, (128, 9)).astype(np.float32)
    host = featurize(hist, 0.7)
    dev = np.asarray(featurize_jnp(jnp.asarray(hist), 0.7))
    assert dev.shape == host.shape
    np.testing.assert_allclose(dev, host, rtol=1e-6, atol=1e-5)
    # integer-exact features are bit-exact
    assert np.array_equal(dev[:, :9], host[:, :9])       # raw histogram
    assert np.array_equal(dev[:, 9], host[:, 9])         # total count


def test_fedspace_search_selects_identical_schedule():
    """The acceptance gate: same rng seed => same selected schedule on the
    device path as on the seed node-walk/host path."""
    rf = _fit_hist_forest(0)
    rng = np.random.default_rng(5)
    K, I0 = 24, 24
    C = rng.random((I0, K)) < 0.2
    state = SS.bootstrap_state(K)
    ref = fedspace_search(np.random.default_rng(7), C, state, 0,
                          _NodeWalkHost(rf), 1.0, num_candidates=512)
    opt = fedspace_search(np.random.default_rng(7), C, state, 0, rf, 1.0,
                          num_candidates=512)
    assert np.array_equal(ref, opt)


def test_infer_n_range_matches_loop_reference():
    rf = _fit_hist_forest(1)

    def reference(regressor, uploads_per_window, I0, status, *, s_max=8,
                  K=None, halfwidth=4):
        best_n, best_u = 1, -np.inf
        n_cap = max(1, I0 // 2)
        total = uploads_per_window * I0
        for n in range(1, n_cap + 1):
            per = total / n
            if K:
                per = min(per, K)
            hist = np.zeros(s_max + 1, np.float32)
            hist[0] = per * 0.7
            hist[1] = per * 0.3
            u = n * float(regressor.predict(featurize(hist[None],
                                                      status))[0])
            if u > best_u:
                best_n, best_u = n, u
        return max(1, best_n - halfwidth), min(n_cap, best_n + halfwidth)

    rng = np.random.default_rng(2)
    upws = [0.5, 2.0, 5.0, 11.0] + list(rng.uniform(0.1, 20.0, 40))
    for upw in upws:
        for K in (None, 16):
            assert infer_n_range(rf, upw, 24, 1.0, K=K) \
                == reference(rf, upw, 24, 1.0, K=K), (upw, K)


# ---------------------------------------------------------------------------
# batched aggregation round


class _SeedLoopEngine(SimulationEngine):
    """`on_aggregate` transcribed from the seed engine: one jitted client
    update per buffered satellite, per-satellite checkpoint fetch,
    sequential compression roundtrip, stack-tensordot-add aggregation."""

    def on_aggregate(self, i):
        from repro.core.staleness import staleness_compensation
        cfg = self.config
        ks = np.flatnonzero(self.buffered_base >= 0)
        stal = self.ig - self.buffered_base[ks]
        updates = []
        for k in ks:
            base = self.store.get(int(self.buffered_base[k]))
            u = self._client_update(base, int(k), round_rng=i,
                                    batch_size=cfg.batch_size)
            if cfg.uplink_topk > 0.0:
                u, _ = roundtrip(u, cfg.uplink_topk)
            updates.append(u)
        stack = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
        c = staleness_compensation(jnp.asarray(stal), cfg.alpha)
        w = c / jnp.maximum(jnp.sum(c), 1e-12) * cfg.server_lr
        delta = jax.tree.map(
            lambda u_: jnp.tensordot(w.astype(jnp.float32),
                                     u_.astype(jnp.float32), axes=1), stack)
        self.params = jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
            self.params, delta)
        self.ig += 1
        self.store.put(self.ig, self.params)
        refs = np.concatenate([self.pending, self.buffered_base])
        refs = refs[refs >= 0]
        self.store.prune(int(refs.min()) if refs.size else self.ig)
        res = self.result
        res.num_global_updates += 1
        res.num_aggregated_gradients += len(ks)
        np.add.at(res.staleness_hist, np.clip(stal, 0, cfg.s_max), 1)
        self.buffered_base[:] = -1
        self._emit("on_aggregate_end", i,
                   {"ig": self.ig, "n_aggregated": len(ks),
                    "staleness": stal.tolist()})


@pytest.fixture(scope="module")
def tiny_world():
    spec = CN.ConstellationSpec(num_satellites=16)
    C = CN.connectivity_sets(spec, days=1.0)
    data = SyntheticFmow(FmowSpec(num_train=800, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(iid_partition(800, 16, 0)))
    return C, adapter


def test_batched_aggregate_bit_identical_trajectory(tiny_world):
    C, adapter = tiny_world
    cfg = dict(eval_every=16, max_windows=64)
    ref_eng = _SeedLoopEngine(C, adapter, make_scheduler("fedbuff", M=4),
                              EngineConfig(**cfg))
    ref = ref_eng.run()
    new_eng = SimulationEngine(C, adapter, make_scheduler("fedbuff", M=4),
                               EngineConfig(**cfg))
    new = new_eng.run()
    assert new.summary() == ref.summary()
    assert new.accuracy == ref.accuracy
    assert new.val_loss == ref.val_loss
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        new_eng.params, ref_eng.params)


def test_batched_aggregate_with_fused_compression(tiny_world):
    """Compressed-uplink mode: the fused in-jit roundtrip matches the
    sequential eager one to ~1 ulp (XLA strength-reduces the /127 dequant
    constant inside the fused program), so the trajectory agrees to float
    noise; all integer protocol counters are exact."""
    C, adapter = tiny_world
    cfg = dict(eval_every=16, max_windows=64, uplink_topk=0.25)
    ref_eng = _SeedLoopEngine(C, adapter, make_scheduler("fedbuff", M=4),
                              EngineConfig(**cfg))
    ref = ref_eng.run()
    new_eng = SimulationEngine(C, adapter, make_scheduler("fedbuff", M=4),
                               EngineConfig(**cfg))
    new = new_eng.run()
    assert new.num_global_updates == ref.num_global_updates
    assert new.num_aggregated_gradients == ref.num_aggregated_gradients
    assert new.staleness_hist.tolist() == ref.staleness_hist.tolist()
    assert new.windows_run == ref.windows_run
    np.testing.assert_allclose(new.val_loss, ref.val_loss, atol=1e-5)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a),
                                                np.asarray(b), atol=1e-7),
        new_eng.params, ref_eng.params)


def test_batched_aggregate_handles_empty_shards():
    """Satellites with empty shards contribute exact-zero updates, batched
    alongside trained ones."""
    K = 8
    rng = np.random.default_rng(0)
    C = rng.random((32, K)) < 0.4
    data = SyntheticFmow(FmowSpec(num_train=200, num_val=50))
    parts = iid_partition(200, K - 2, 0) + [np.array([], np.int64)] * 2
    adapter = MlpFmowAdapter(data, make_clients(parts))
    cfg = dict(eval_every=16, max_windows=32)
    ref = _SeedLoopEngine(C, adapter, make_scheduler("async"),
                          EngineConfig(**cfg)).run()
    new = SimulationEngine(C, adapter, make_scheduler("async"),
                           EngineConfig(**cfg)).run()
    assert new.summary() == ref.summary()
    assert new.accuracy == ref.accuracy


# ---------------------------------------------------------------------------
# aggregation kernel routing


def _rand_tree(rng, M):
    params = {"w": jnp.asarray(rng.normal(size=(17, 23)).astype(np.float32)),
              "b": jnp.asarray(rng.normal(size=(11,)).astype(np.float32))}
    upds = jax.tree.map(
        lambda p: jnp.asarray(
            rng.normal(size=(M,) + p.shape).astype(np.float32)), params)
    w = jnp.asarray(rng.random(M).astype(np.float32))
    return params, upds, w


def test_aggregate_params_tree_interpret_matches_tensordot():
    rng = np.random.default_rng(3)
    params, upds, w = _rand_tree(rng, 6)
    ref = jax.tree.map(
        lambda p, u: p + jnp.tensordot(w, u.astype(jnp.float32), axes=1),
        params, upds)
    interp = aggregate_params_tree(params, upds, w, interpret=True)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5), interp, ref)


@pytest.mark.skipif(on_tpu(), reason="off-TPU dispatch contract")
def test_aggregate_params_tree_default_bitmatches_tensordot_off_tpu():
    """The engine's default dispatch must stay bit-identical to the eager
    tensordot reduction the seed engine used."""
    rng = np.random.default_rng(4)
    params, upds, w = _rand_tree(rng, 9)
    ref = jax.tree.map(
        lambda p, u: p + jnp.tensordot(w, u.astype(jnp.float32), axes=1),
        params, upds)
    out = aggregate_params_tree(params, upds, w)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), out, ref)
