"""Link-budget layer: station-level contact accounting, deterministic
contention at shared ground stations, multi-window transfer gating, and
the parity contracts — infinite capacity must equal raw geometry, and the
schedule search must pick identical schedules under a trivial gate."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import connectivity as CN
from repro.core import staleness as SS
from repro.core.search import fedspace_search
from repro.core.utility import RandomForestRegressor, featurize


# ---------------------------------------------------------------------------
# physics: transfer_windows / station_windows / resolve_contention


def test_transfer_windows_arithmetic():
    # 600 MB at 20 Mbit/s = 240 s = 4 sixty-second substeps
    assert CN.transfer_windows(20.0, 600.0, 60.0) == 4
    assert CN.transfer_windows(100.0, 600.0, 60.0) == 1   # ceil(48/60)
    # unconstrained sentinels
    assert CN.transfer_windows(0.0, 600.0) == 0
    assert CN.transfer_windows(20.0, 0.0) == 0


def test_station_windows_matches_connectivity_sets():
    """Collapsing the per-station contact counts must reproduce the
    geometry-only connectivity matrix bit-for-bit."""
    spec = CN.ConstellationSpec(num_satellites=16)
    C = CN.connectivity_sets(spec, days=0.25)
    counts = CN.station_windows(spec, days=0.25)
    assert counts.shape == (C.shape[0], 16, len(spec.ground_stations))
    np.testing.assert_array_equal((counts > 0).any(axis=-1), C)


def test_resolve_contention_unlimited_serves_all():
    counts = np.array([[[3, 0], [2, 5], [0, 0]]], np.int32)  # (1, K=3, G=2)
    assign = CN.resolve_contention(counts, 0)
    # longest-contact station wins; invisible satellite unserved
    assert assign.tolist() == [[0, 1, -1]]


def test_resolve_contention_capacity_and_order():
    # one station, capacity 1, both sats visible: longest contact first
    counts = np.array([[[2], [5]]], np.int32)                # (1, K=2, G=1)
    assert CN.resolve_contention(counts, 1).tolist() == [[-1, 0]]
    # tie on contact length -> lowest satellite index
    counts = np.array([[[4], [4]]], np.int32)
    assert CN.resolve_contention(counts, 1).tolist() == [[0, -1]]
    # stations claim in index order: station 0 takes sat 1 (its longest),
    # then station 1 still serves sat 0
    counts = np.array([[[2, 9], [5, 9]]], np.int32)
    assert CN.resolve_contention(counts, 1).tolist() == [[1, 0]]


def test_link_budget_unlimited_is_geometry():
    spec = CN.ConstellationSpec(num_satellites=12)
    C = CN.connectivity_sets(spec, days=0.25)
    b = CN.link_budget(spec, days=0.25)
    np.testing.assert_array_equal(b.served, C)
    np.testing.assert_array_equal(b.visible, C)
    assert b.need_up == 0 and b.need_dn == 0
    assert b.blocked_fraction() == 0.0
    assert (b.grants[b.served] > 0).all()
    assert (b.grants[~b.served] == 0).all()


def test_link_budget_capacity_blocks_contacts():
    spec = CN.constellation_preset("flock191", ground="sparse1")
    b = CN.link_budget(spec, days=0.25, gs_capacity=2)
    assert (b.served <= b.visible).all()
    assert b.blocked_fraction() > 0.1     # a single station saturates
    # never more than `capacity` sats on one station per window
    for i in range(b.num_windows):
        served = b.assign[i][b.assign[i] >= 0]
        _, n = np.unique(served, return_counts=True)
        assert (n <= 2).all()


# ---------------------------------------------------------------------------
# protocol gating: multi-window uploads/downloads


def test_multi_window_upload_and_download():
    """need_up=2 at 1 unit/window: the upload enters the buffer on the
    second contact; need_dn=2: the new model arrives two contacts after
    the aggregation."""
    K, I = 1, 7
    C = np.ones((I, K), bool)
    a = jnp.asarray(np.array([0, 0, 1, 0, 0, 0, 0], np.int32))
    gate = SS.LinkGate(jnp.ones((I, K), jnp.int32), jnp.int32(2),
                       jnp.int32(2))
    st = SS.bootstrap_state(K, progress=True)
    ig = jnp.int32(0)
    hist = []
    for i in range(I):
        st, ig, _ = SS.step(st, ig, jnp.asarray(C[i]), a[i].astype(bool),
                            s_max=8, link=SS.LinkGate(gate.grant[i],
                                                      gate.need_up,
                                                      gate.need_dn))
        hist.append((int(st.pending[0]), int(st.buffered[0]),
                     int(st.version[0]), int(ig), int(st.progress[0])))
    assert hist == [
        (0, -1, 0, 0, 1),    # w0: uploading, 1/2 units
        (-1, 0, 0, 0, 0),    # w1: upload complete -> buffer
        (-1, -1, 0, 1, 1),   # w2: aggregation; download starts, 1/2
        (1, -1, 1, 1, 0),    # w3: download complete -> new local round
        (1, -1, 1, 1, 1),    # w4: uploading the new round, 1/2
        (-1, 1, 1, 1, 0),    # w5: second upload complete
        (-1, 1, 1, 1, 0),    # w6: nothing to do (idle contact)
    ]


def test_upload_drains_before_download():
    """A satellite mid-upload does not accumulate download progress even
    when a newer global model exists."""
    st = SS.SatState(version=jnp.zeros((1,), jnp.int32),
                     pending=jnp.zeros((1,), jnp.int32),
                     buffered=jnp.full((1,), -1, jnp.int32),
                     progress=jnp.zeros((1,), jnp.int32))
    gate = SS.LinkGate(jnp.ones((1,), jnp.int32), jnp.int32(3),
                       jnp.int32(1))
    conn = jnp.ones((1,), bool)
    # ig=2 (model is ahead): the sat uploads for 3 windows before any
    # download despite need_dn=1
    ig = jnp.int32(2)
    for w in range(2):
        st, _, _ = SS.step(st, ig, conn, jnp.bool_(False), s_max=8,
                           link=gate)
        assert int(st.version[0]) == 0 and int(st.pending[0]) == 0, w
    st, _, _ = SS.step(st, ig, conn, jnp.bool_(False), s_max=8, link=gate)
    # upload completed on window 3; download then starts fresh and
    # completes immediately (need_dn=1, same-window grant)
    assert int(st.buffered[0]) == 0
    assert int(st.version[0]) == 2 and int(st.pending[0]) == 2


def test_progress_persists_across_contact_gaps():
    """A partial transfer resumes at the next contact instead of
    restarting."""
    C = np.array([[True], [False], [False], [True]], bool)
    gate = SS.LinkGate(jnp.asarray(np.where(C, 1, 0).astype(np.int32)),
                       jnp.int32(2), jnp.int32(0))
    st, ig, _ = SS.simulate_window(
        jnp.asarray(C), jnp.asarray(np.zeros(4, np.int32)),
        SS.bootstrap_state(1, progress=True), jnp.int32(0), link=gate)
    # 1 unit at w0 + 1 unit at w3 completes the 2-unit upload
    assert int(st.buffered[0]) == 0 and int(st.pending[0]) == -1


# ---------------------------------------------------------------------------
# search parity and experiment wiring


def _forest(s_max=8, seed=0):
    rng = np.random.default_rng(seed)
    hists = rng.integers(0, 25, (300, s_max + 1)).astype(np.float32)
    X = featurize(hists, 1.0)
    y = (hists.sum(1) + rng.normal(size=300)).astype(np.float32)
    return RandomForestRegressor(n_trees=10, max_depth=5, seed=seed).fit(
        X, y)


def test_search_trivial_gate_identical_schedule():
    """fedspace_search under the zero-need gate must select the identical
    schedule to the geometry-only search (both scoring backends share the
    candidate stream and the selection rule)."""
    rng = np.random.default_rng(0)
    K, I0 = 16, 12
    C = rng.random((I0, K)) < 0.2
    rf = _forest()
    base = fedspace_search(np.random.default_rng(7), C,
                           SS.bootstrap_state(K), 0, rf, 1.0,
                           num_candidates=256, s_max=8)
    gate = SS.LinkGate(np.ones((I0, K), np.int32) * C, 0, 0)
    gated = fedspace_search(np.random.default_rng(7), C,
                            SS.bootstrap_state(K, progress=True), 0, rf,
                            1.0, num_candidates=256, s_max=8, link=gate)
    np.testing.assert_array_equal(base, gated)


def test_fedspace_search_state_undoes_boundary_upload():
    """The search receives the post-upload state at a re-plan boundary
    and its rollout re-simulates that window's upload. With link gating
    the re-run is NOT idempotent (progress already holds the window's
    grant), so FedSpace must hand the search an inverted state —
    re-applying the gated upload_step on it must land exactly back on the
    engine's state, for in-flight and completed uploads alike."""
    from repro.core.scheduler import FedSpaceScheduler
    K = 4
    conn = jnp.asarray(np.array([True, True, True, False]))
    grants = np.array([[2, 2, 2, 2]], np.int32)
    gate = SS.LinkGate(jnp.asarray(grants[0]), jnp.int32(3), jnp.int32(1))
    # sat0 mid-upload, sat1 completes this window (progress 1 + 2 >= 3),
    # sat2 starts fresh, sat3 disconnected mid-upload
    pre = SS.SatState(version=jnp.zeros((K,), jnp.int32),
                      pending=jnp.asarray([0, 0, 0, 0], jnp.int32),
                      buffered=jnp.full((K,), -1, jnp.int32),
                      progress=jnp.asarray([0, 1, 0, 1], jnp.int32))
    post, _ = SS.upload_step(pre, jnp.int32(0), conn, gate)
    assert np.asarray(post.progress).tolist() == [2, 0, 2, 1]
    assert np.asarray(post.pending).tolist() == [0, -1, 0, 0]
    run_link = SS.LinkGate(grants, 3, 1)
    undone = FedSpaceScheduler._search_state(
        post, 0, connectivity=np.asarray(conn)[None, :], link=run_link)
    # the rollout's own upload_step for window 0 must reproduce `post`
    redo, _ = SS.upload_step(undone, jnp.int32(0), conn, gate)
    for a, b in zip(redo, post):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and without the inversion it would not (the double-count this pins)
    redo2, _ = SS.upload_step(post, jnp.int32(0), conn, gate)
    assert np.asarray(redo2.progress).tolist() != \
        np.asarray(post.progress).tolist()


def test_search_host_and_device_backends_agree_under_gate():
    """The `.predict`-only fallback path and the on-device marks path must
    select the same schedule for the same finite link gate."""

    class HostOnly:
        def __init__(self, rf):
            self.predict = rf.predict

    rng = np.random.default_rng(3)
    K, I0 = 10, 12
    C = rng.random((I0, K)) < 0.3
    grants = (rng.integers(0, 3, (I0, K)) * C).astype(np.int32)
    gate = SS.LinkGate(grants, 2, 1)
    rf = _forest(seed=3)
    st = SS.bootstrap_state(K, progress=True)
    dev = fedspace_search(np.random.default_rng(7), C, st, 0, rf, 1.0,
                          num_candidates=128, s_max=8, link=gate)
    host = fedspace_search(np.random.default_rng(7), C, st, 0,
                           HostOnly(rf), 1.0, num_candidates=128, s_max=8,
                           link=gate)
    np.testing.assert_array_equal(dev, host)


def test_linkconfig_constrained_predicate():
    from repro.fl.api import LinkConfig
    assert not LinkConfig().constrained
    assert not LinkConfig(uplink_topk=0.1).constrained
    assert not LinkConfig(model_mb=100).constrained        # no rate
    assert not LinkConfig(uplink_mbps=20).constrained      # no size
    assert LinkConfig(gs_capacity=2).constrained
    assert LinkConfig(model_mb=100, uplink_mbps=20).constrained
    assert LinkConfig(model_mb=100, downlink_mbps=50).constrained


def test_federation_builds_and_runs_link_budget():
    from repro.fl.api import (ConstellationConfig, DatasetConfig,
                              FLExperiment, Federation, LinkConfig)
    from repro.fl.engine import EngineConfig
    exp = FLExperiment(
        constellation=ConstellationConfig(num_satellites=10, days=0.25),
        dataset=DatasetConfig(num_train=200, num_val=80),
        train=EngineConfig(eval_every=12, max_windows=24, local_steps=2),
        link=LinkConfig(uplink_mbps=20, downlink_mbps=100, model_mb=300,
                        gs_capacity=1),
    )
    fed = Federation.from_experiment(exp)
    assert fed.link_budget is not None
    assert fed.link_budget.need_up == 2 and fed.link_budget.need_dn == 1
    eng = fed.engine()
    res = eng.run()
    assert res.windows_run == 24
    assert eng.transfer_progress is not None
    # the default (unconstrained) LinkConfig builds no budget
    exp2 = FLExperiment(
        constellation=ConstellationConfig(num_satellites=10, days=0.25),
        dataset=DatasetConfig(num_train=200, num_val=80),
        train=EngineConfig(eval_every=12, max_windows=24, local_steps=2))
    fed2 = Federation.from_experiment(exp2)
    assert fed2.link_budget is None
    assert fed2.engine().run().windows_run == 24


def test_hotpaths_registers_all_sections_with_parity_gates():
    """The benchmark runner iterates its section registry and fails on
    omission — this pins the registry itself, so neither the link-budget
    section nor any older parity gate can be dropped quietly."""
    hp = pytest.importorskip("benchmarks.hotpaths")
    expected = {"search_replan", "search_scaling", "aggregation_round",
                "window_loop", "utility_sampler", "link_budget", "isl",
                "faults", "sweep_scaling", "payloads", "replan"}
    assert expected <= set(hp.SECTIONS)
    for name in expected:
        fn, parity = hp.SECTIONS[name]
        assert callable(fn) and parity is not None, name


def test_with_scheduler_shares_link_budget():
    from repro.fl.api import (ConstellationConfig, DatasetConfig,
                              FLExperiment, Federation, LinkConfig)
    from repro.fl.engine import EngineConfig
    exp = FLExperiment(
        constellation=ConstellationConfig(num_satellites=8, days=0.25),
        dataset=DatasetConfig(num_train=160, num_val=60),
        train=EngineConfig(eval_every=12, max_windows=12, local_steps=2),
        link=LinkConfig(gs_capacity=1),
    )
    fed = Federation.from_experiment(exp)
    fed2 = fed.with_scheduler("async")
    assert fed2.link_budget is fed.link_budget
    assert fed2.run().windows_run == 12
