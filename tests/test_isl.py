"""ISL subsystem tests: ring-topology invariants (property-tested over
random multi-shell specs), sink election, the device-resident relay/gossip
transitions, fast-vs-host engine lockstep for both ISL schedulers, the
identity-topology parity gate, and the `isl=None` bit-identity guarantee
(engine strategies and the eq.-13 search alike)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import isl as ISL
from repro.core import staleness as SS
from repro.core.connectivity import (ConstellationSpec, LinkBudget, Shell,
                                     constellation_preset)
from repro.core.scheduler import make_scheduler
from repro.fl.engine import EngineConfig, SimulationEngine
from repro.fl.registry import SCHEDULERS


class _StubAdapter:
    """Zero-gradient adapter: runs isolate the protocol dynamics."""

    def __init__(self, K):
        self.clients = list(range(K))

    def init(self, key):
        return {"w": jnp.zeros((2,))}

    def loss(self, params, batch):
        return jnp.sum(params["w"]) * 0.0 + jnp.sum(batch) * 0.0

    def client_batch(self, ci, round_rng, batch_size, num_batches):
        return jnp.zeros((num_batches, 1))

    def accuracy(self, params):
        return 0.0

    def val_loss(self, params):
        return 0.0


# --------------------------------------------------------------------------
# ring-topology invariants


@st.composite
def _multi_shell_spec(draw):
    """Random 1-3 shell Walker spec (small satellite counts)."""
    shells = []
    for s in range(draw(st.integers(1, 3))):
        planes = draw(st.integers(1, 4))
        per_plane = draw(st.integers(1, 5))
        shells.append(Shell(planes * per_plane, planes,
                            500_000.0 + 20_000.0 * s,
                            50.0 + 20.0 * s))
    shells = tuple(shells)
    return ConstellationSpec(
        num_satellites=sum(sh.num_satellites for sh in shells),
        shells=shells, seed=draw(st.integers(0, 10)))


def _check_ring_invariants(spec, topo):
    K = spec.num_satellites
    idx = np.arange(K)
    # links never leave the plane (and hence never cross shells)
    assert (topo.plane[topo.nxt] == topo.plane).all()
    assert (topo.plane[topo.prv] == topo.plane).all()
    shell = ISL._shell_ids(spec)
    assert (shell[topo.nxt] == shell).all()
    assert (shell[topo.left] == shell).all()
    assert (shell[topo.right] == shell).all()
    # symmetric ring: prv inverts nxt, so every link is traversed both ways
    assert (topo.prv[topo.nxt] == idx).all()
    assert (topo.nxt[topo.prv] == idx).all()
    sizes = topo.plane_sizes()
    for p in range(topo.num_planes):
        m = np.flatnonzero(topo.plane == p)
        n = m.size
        assert sizes[p] == n
        if n == 1:
            assert topo.nxt[m[0]] == m[0] == topo.prv[m[0]]
            continue
        # 2-regular closed ring: following nxt visits every member once
        seen, k = set(), m[0]
        for _ in range(n):
            assert k not in seen
            seen.add(int(k))
            assert topo.nxt[k] != k and topo.prv[k] != k
            k = topo.nxt[k]
        assert k == m[0] and len(seen) == n
        # ring positions are a permutation of 0..n-1 in nxt order
        assert sorted(topo.pos[m].tolist()) == list(range(n))
        assert (topo.pos[topo.nxt[m]] == (topo.pos[m] + 1) % n).all()


@settings(max_examples=15, deadline=None)
@given(_multi_shell_spec())
def test_ring_topology_invariants(spec):
    """Symmetric, 2-regular-per-plane rings that never cross shells, for
    random multi-shell Walker specs."""
    topo = ISL.ring_topology(spec)
    assert topo.num_planes == sum(sh.num_planes for sh in spec.shells)
    _check_ring_invariants(spec, topo)


def test_ring_topology_legacy_single_shell():
    """The legacy single-shell path (paper's Planet-Flock mix) splits into
    physical planes — sun-synchronous and ISS-orbit satellites never share
    a ring — and derivation is deterministic in the spec."""
    spec = constellation_preset("flock191")
    topo = ISL.ring_topology(spec)
    _check_ring_invariants(spec, topo)
    # ISS-orbit satellites (different inclination/altitude) get their own
    # planes: both orbit families present, no ring mixes them
    from repro.core.connectivity import satellite_elements
    _, inc, _, _ = satellite_elements(spec)
    for p in range(topo.num_planes):
        m = np.flatnonzero(topo.plane == p)
        assert np.unique(np.round(inc[m], 9)).size == 1
    assert np.unique(np.round(inc, 9)).size == 2
    topo2 = ISL.ring_topology(constellation_preset("flock191"))
    np.testing.assert_array_equal(topo.nxt, topo2.nxt)
    np.testing.assert_array_equal(topo.plane, topo2.plane)


def test_grid_neighbors_stay_in_shell_and_wrap():
    """Cross-plane grid links connect adjacent planes of the SAME shell
    (wrapping over RAAN order), self-loops for single-plane shells."""
    spec = constellation_preset("starlink40")
    topo = ISL.ring_topology(spec)
    shell = ISL._shell_ids(spec)
    assert (shell[topo.left] == shell).all()
    assert (shell[topo.right] == shell).all()
    # both starlink40 shells have 4 planes: every grid link leaves the
    # plane but stays in the shell
    assert (topo.plane[topo.left] != topo.plane).all()
    assert (topo.plane[topo.right] != topo.plane).all()


def test_identity_topology_is_all_self_loops():
    topo = ISL.identity_topology(7)
    idx = np.arange(7)
    for arr in (topo.nxt, topo.prv, topo.left, topo.right):
        np.testing.assert_array_equal(arr, idx)
    np.testing.assert_array_equal(topo.plane, idx)
    assert topo.num_planes == 7
    np.testing.assert_array_equal(topo.ring_distance(idx), np.zeros(7))


# --------------------------------------------------------------------------
# sink election & reachability


def test_elect_sinks_earliest_contact_wins():
    topo = ISL.ring_topology(ConstellationSpec(
        num_satellites=8, shells=(Shell(8, 2, 550_000.0, 53.0),)))
    K = 8
    C = np.zeros((6, K), bool)
    p0 = np.flatnonzero(topo.plane == 0)
    p1 = np.flatnonzero(topo.plane == 1)
    C[3, p0[2]] = True          # plane 0: only member with a contact
    C[1, p1[1]] = True          # plane 1: earliest ...
    C[2, p1[3]] = True          # ... beats later
    sink = ISL.elect_sinks(C, topo)
    assert (sink[p0] == p0[2]).all()
    assert (sink[p1] == p1[1]).all()
    # ties on first contact: most total contacts, then lowest index
    C2 = np.zeros((6, K), bool)
    C2[1, p1[1]] = True
    C2[1, p1[3]] = True
    C2[4, p1[3]] = True
    assert (ISL.elect_sinks(C2, topo)[p1] == p1[3]).all()
    # no contact at all: lowest-index member
    assert (ISL.elect_sinks(np.zeros((6, K), bool), topo)[p0]
            == p0.min()).all()
    # sinks always stay in their plane
    assert (topo.plane[sink] == topo.plane).all()


def test_reachable_count():
    topo = ISL.ring_topology(ConstellationSpec(
        num_satellites=8, shells=(Shell(8, 2, 550_000.0, 53.0),)))
    C = np.zeros((4, 8), bool)
    assert ISL.reachable_count(topo, C) == 0
    C[0, np.flatnonzero(topo.plane == 1)[0]] = True
    assert ISL.reachable_count(topo, C) == 4     # the whole touched plane
    C[2, np.flatnonzero(topo.plane == 0)[2]] = True
    assert ISL.reachable_count(topo, C) == 8


def test_sink_plan_scales_ring_distance_by_hop_latency():
    spec = ConstellationSpec(num_satellites=8,
                             shells=(Shell(8, 1, 550_000.0, 53.0),))
    topo = ISL.ring_topology(spec)
    for rw in (0, 3):
        runtime = ISL.ISL(topology=topo, relay_windows=rw, epoch=4)
        C = np.zeros((4, 8), bool)
        C[0, 5] = True
        sink, need = runtime.sink_plan(C)
        assert (sink == 5).all()
        np.testing.assert_array_equal(need,
                                      topo.ring_distance(sink) * rw)
        assert need[5] == 0                       # the sink itself
        assert need.max() == 4 * rw               # ring diameter of 8


# --------------------------------------------------------------------------
# device transitions


def test_relay_step_and_reset():
    state = SS.bootstrap_state(4, relay=True)       # everyone pending
    need = jnp.asarray([0, 1, 2, 5], jnp.int32)
    state, arrived = ISL.relay_step(state, need)
    np.testing.assert_array_equal(np.asarray(state.relay), [1, 1, 1, 1])
    np.testing.assert_array_equal(np.asarray(arrived),
                                  [True, True, False, False])
    # uploaded satellites (pending < 0) stop accumulating
    state = state._replace(pending=jnp.asarray([-1, 0, 0, 0], jnp.int32))
    state, arrived = ISL.relay_step(state, need)
    np.testing.assert_array_equal(np.asarray(state.relay), [1, 2, 2, 2])
    np.testing.assert_array_equal(np.asarray(arrived),
                                  [True, True, True, False])
    state = ISL.reset_relay(state, jnp.asarray([True, False, True, False]))
    np.testing.assert_array_equal(np.asarray(state.relay), [0, 2, 0, 2])


def test_sink_connectivity_semantics():
    conn = jnp.asarray([True, False, False, False])
    sink = jnp.asarray([0, 0, 3, 3], jnp.int32)
    arrived = jnp.asarray([True, False, True, False])
    pending = jnp.asarray([0, 0, 0, -1], jnp.int32)
    eff = np.asarray(ISL.sink_connectivity(conn, sink, arrived, pending))
    # k=0: sink 0 connected & arrived -> True; k=1: not arrived, pending
    # in transit -> False; k=2: sink 3 has no contact -> False; k=3:
    # nothing pending rides the sink contact, but sink 3 is dark -> False
    np.testing.assert_array_equal(eff, [True, False, False, False])
    eff2 = np.asarray(ISL.sink_connectivity(
        conn, jnp.zeros(4, jnp.int32), arrived, pending))
    # all on sink 0: arrived or idle pass, un-arrived transit blocks
    np.testing.assert_array_equal(eff2, [True, False, True, True])


def test_gossip_step_adopts_newer_neighbour_versions():
    idx = jnp.arange(4, dtype=jnp.int32)
    nxt = jnp.asarray([1, 2, 3, 0], jnp.int32)
    prv = jnp.asarray([3, 0, 1, 2], jnp.int32)
    state = SS.init_state(4, relay=False)._replace(
        version=jnp.asarray([5, 0, 0, 0], jnp.int32),
        pending=jnp.asarray([-1, 0, 0, 0], jnp.int32))
    state, adopted = ISL.gossip_step(state, nxt, prv, idx, idx,
                                     jnp.bool_(True))
    # ring neighbours of the version-5 holder adopt it and restart local
    # training on it; the opposite side of the ring hasn't heard yet
    np.testing.assert_array_equal(np.asarray(state.version), [5, 5, 0, 5])
    np.testing.assert_array_equal(np.asarray(state.pending), [-1, 5, 0, 5])
    np.testing.assert_array_equal(np.asarray(adopted),
                                  [False, True, False, True])
    # do_hop=False is a frozen no-op
    st2, adopted = ISL.gossip_step(state, nxt, prv, idx, idx,
                                   jnp.bool_(False))
    np.testing.assert_array_equal(np.asarray(st2.version),
                                  np.asarray(state.version))
    assert not np.asarray(adopted).any()


# --------------------------------------------------------------------------
# engine integration: lockstep, parity gates, isl=None bit-identity


@st.composite
def _world(draw):
    """Random connectivity over a small 2-shell constellation."""
    spec = ConstellationSpec(
        num_satellites=10, shells=(Shell(6, 2, 550_000.0, 53.0),
                                   Shell(4, 1, 560_000.0, 97.6)),
        seed=draw(st.integers(0, 5)))
    I = draw(st.integers(8, 30))
    C = np.array(draw(st.lists(
        st.lists(st.booleans(), min_size=10, max_size=10),
        min_size=I, max_size=I)), bool)
    return spec, C


def _run(C, adapter, sched, *, fast, isl=None, budget=None):
    eng = SimulationEngine(
        C, adapter, sched,
        EngineConfig(eval_every=C.shape[0] + 1, fast_loop=fast),
        isl=isl, link_budget=budget)
    res = eng.run()
    assert eng._fast_ok == fast
    return eng, res


def _assert_same_trajectory(a, b, res_a=None, res_b=None):
    np.testing.assert_array_equal(a.version, b.version)
    np.testing.assert_array_equal(a.pending, b.pending)
    np.testing.assert_array_equal(a.buffered_base, b.buffered_base)
    assert a.ig == b.ig
    if res_a is not None:
        assert res_a.idle_connections == res_b.idle_connections
        assert res_a.total_connections == res_b.total_connections
        assert res_a.staleness_hist.tolist() == \
            res_b.staleness_hist.tolist()


@settings(max_examples=8, deadline=None)
@given(_world(), st.integers(0, 2), st.integers(4, 12))
def test_isl_engine_fast_host_lockstep(world, relay_windows, epoch):
    """Both ISL schedulers traverse identical protocol state under the
    chunked fast loop and the per-window host loop — for instantaneous and
    multi-window hop latencies and different election epochs."""
    spec, C = world
    runtime = ISL.ISL(topology=ISL.ring_topology(spec),
                      relay_windows=relay_windows, epoch=epoch)
    K = C.shape[1]
    for name, kw in (("intra_plane", {"M": 3}), ("isl_async", {})):
        ef, rf = _run(C, _StubAdapter(K), make_scheduler(name, **kw),
                      fast=True, isl=runtime)
        eh, rh = _run(C, _StubAdapter(K), make_scheduler(name, **kw),
                      fast=False, isl=runtime)
        _assert_same_trajectory(ef, eh, rf, rh)
        if name == "intra_plane":
            np.testing.assert_array_equal(ef.relay_units, eh.relay_units)


@settings(max_examples=8, deadline=None)
@given(_world())
def test_identity_topology_parity_with_fedbuff(world):
    """The degenerate all-self-loop topology must reproduce the plain
    ground-only fedbuff trajectory bit-for-bit under both strategies —
    the gate the `isl` benchmark section enforces in CI."""
    spec, C = world
    K = C.shape[1]
    ident = ISL.ISL(topology=ISL.identity_topology(K), relay_windows=0,
                    epoch=8)
    ref, ref_res = _run(C, _StubAdapter(K), make_scheduler("fedbuff", M=3),
                        fast=True)
    for fast in (True, False):
        eng, res = _run(C, _StubAdapter(K),
                        make_scheduler("intra_plane", M=3), fast=fast,
                        isl=ident)
        _assert_same_trajectory(eng, ref, res, ref_res)
        assert eng.relay_units is not None       # the column exists...
    # ...and gossip over self-loops is likewise invisible
    for fast in (True, False):
        eng, res = _run(C, _StubAdapter(K),
                        make_scheduler("isl_async", M=3), fast=fast,
                        isl=ident)
        _assert_same_trajectory(eng, ref, res, ref_res)


@settings(max_examples=6, deadline=None)
@given(_world())
def test_ground_only_scheduler_ignores_isl_runtime(world):
    """A scheduler without an `isl_mode` runs bit-identically with and
    without an ISL runtime attached — one ISL-configured world serves
    with/without-ISL comparisons."""
    spec, C = world
    K = C.shape[1]
    runtime = ISL.ISL(topology=ISL.ring_topology(spec), relay_windows=1,
                      epoch=8)
    for fast in (True, False):
        ref, ref_res = _run(C, _StubAdapter(K),
                            make_scheduler("fedbuff", M=3), fast=fast)
        eng, res = _run(C, _StubAdapter(K), make_scheduler("fedbuff", M=3),
                        fast=fast, isl=runtime)
        _assert_same_trajectory(eng, ref, res, ref_res)
        assert eng.state.relay is None and eng.relay_units is None


@settings(max_examples=6, deadline=None)
@given(_world(), st.integers(1, 3))
def test_isl_lockstep_under_link_budget(world, cap):
    """ISL relaying composes with finite link budgets: fast and host
    strategies stay in lockstep when every upload/download is also gated
    on accumulated sink-contact units."""
    spec, C = world
    I, K = C.shape
    grants = (np.ones(C.shape, np.int32) * cap) * C
    budget = LinkBudget(visible=C, served=C,
                        assign=np.where(C, 0, -1).astype(np.int32),
                        grants=grants, need_up=2, need_dn=1)
    runtime = ISL.ISL(topology=ISL.ring_topology(spec), relay_windows=1,
                      epoch=8)
    for name in ("intra_plane", "isl_async"):
        ef, rf = _run(C, _StubAdapter(K), make_scheduler(name, M=3),
                      fast=True, isl=runtime, budget=budget)
        eh, rh = _run(C, _StubAdapter(K), make_scheduler(name, M=3),
                      fast=False, isl=runtime, budget=budget)
        _assert_same_trajectory(ef, eh, rf, rh)


def test_search_accepts_relay_column():
    """The eq.-13 scorer passes the relay column through untouched — a
    state captured mid-ISL-run scores identically to one without the
    column (ground-only candidate simulation either way)."""
    from repro.core.search import score_candidates

    class _Oracle:
        def predict(self, feats):
            return np.ones(feats.shape[0], np.float32)

    rng = np.random.default_rng(0)
    C = rng.random((12, 6)) < 0.4
    cands = np.asarray(rng.random((8, 12)) < 0.3, np.int32)
    plain = SS.bootstrap_state(6)
    with_relay = SS.bootstrap_state(6, relay=True)
    s0 = score_candidates(cands, C, plain, 0, _Oracle(), 1.0)
    s1 = score_candidates(cands, C, with_relay, 0, _Oracle(), 1.0)
    np.testing.assert_array_equal(s0, s1)


def test_registry_and_federation_wiring():
    """The ISL schedulers are registered with their modes; FLExperiment.isl
    resolves to a runtime shared across `with_scheduler` clones."""
    from repro.fl.api import (ConstellationConfig, DatasetConfig,
                              FLExperiment, Federation, ISLConfig,
                              SchedulerConfig)

    assert "intra_plane" in SCHEDULERS.names()
    assert "isl_async" in SCHEDULERS.names()
    assert make_scheduler("intra_plane").isl_mode == "sink"
    assert make_scheduler("isl_async").isl_mode == "gossip"
    assert make_scheduler("fedbuff", M=1).isl_mode is None

    cfg = ISLConfig(isl_mbps=4.0, model_mb=600.0)
    assert cfg.relay_windows == 2       # ceil(600*8 / 4.0 / 900) = 2
    exp = FLExperiment(
        constellation=ConstellationConfig(preset="starlink40", days=0.25),
        dataset=DatasetConfig(num_train=60, num_val=30),
        scheduler=SchedulerConfig(kind="intra_plane"),
        isl=cfg)
    fed = Federation.from_experiment(exp)
    assert fed.isl is not None
    assert fed.isl.relay_windows == cfg.relay_windows
    assert fed.isl.topology.num_planes == 8
    fed2 = fed.with_scheduler("isl_async")
    assert fed2.isl is fed.isl
    # isl=None experiments resolve to no runtime
    assert Federation.from_experiment(FLExperiment(
        constellation=ConstellationConfig(preset="starlink40", days=0.25),
        dataset=DatasetConfig(num_train=60, num_val=30))).isl is None


def test_intra_plane_threshold_resolution():
    """intra_plane's default M is the reachable-satellite count (planes
    with at least one effective contact); an explicit M overrides it, and
    without an ISL runtime it degrades to a sync-over-K barrier."""
    spec = ConstellationSpec(num_satellites=8,
                             shells=(Shell(8, 2, 550_000.0, 53.0),))
    topo = ISL.ring_topology(spec)
    C = np.zeros((6, 8), bool)
    C[0, np.flatnonzero(topo.plane == 0)[0]] = True    # one plane reachable
    runtime = ISL.ISL(topology=topo, relay_windows=0, epoch=6)

    s = make_scheduler("intra_plane")
    s.isl = runtime
    s.reset()
    assert s._threshold(C, 8) == 4
    s2 = make_scheduler("intra_plane", M=2)
    s2.isl = runtime
    s2.reset()
    assert s2._threshold(C, 8) == 2
    s3 = make_scheduler("intra_plane")
    s3.isl = None
    s3.reset()
    assert s3._threshold(C, 8) == 8
