"""Connectivity-model tests: determinism, physical sanity, and the Fig. 2
qualitative statistics."""
import numpy as np
import pytest

from repro.core import connectivity as CN


@pytest.fixture(scope="module")
def small_spec():
    return CN.ConstellationSpec(num_satellites=24)


def test_deterministic(small_spec):
    C1 = CN.connectivity_sets(small_spec, days=0.5)
    C2 = CN.connectivity_sets(small_spec, days=0.5)
    assert (C1 == C2).all()


def test_orbit_radius_and_period(small_spec):
    times = np.arange(0, 6000, 60.0)
    pos = CN.satellite_positions_eci(small_spec, times)
    r = np.linalg.norm(pos, axis=-1)
    # circular orbits at their configured altitudes
    assert r.min() > CN.R_EARTH + 400_000 - 1
    assert r.max() < CN.R_EARTH + 480_000 + 1
    # LEO period ~ 5500-5700 s: position approximately repeats
    n = np.sqrt(CN.MU / (CN.R_EARTH + 475_000) ** 3)
    period = 2 * np.pi / n
    assert 5400 < period < 5800


def test_ground_stations_rotate(small_spec):
    t = np.array([0.0, 43200.0])   # half a day: Earth rotates ~180 deg
    gs = CN.ground_positions_eci(small_spec, t)
    equatorish = np.argmin(np.abs([g[1] for g in
                                   small_spec.ground_stations]))
    v0, v1 = gs[0, equatorish, :2], gs[1, equatorish, :2]
    cos = v0 @ v1 / (np.linalg.norm(v0) * np.linalg.norm(v1))
    assert cos < -0.9   # roughly opposite side


def test_fig2_statistics_full_constellation():
    spec = CN.ConstellationSpec()        # 191 sats, 12 GS
    C = CN.connectivity_sets(spec, days=1.0)
    st = CN.connectivity_stats(C)
    # paper Fig. 2: |C_i| varies widely (4..68); n_k in [5, 19]
    assert C.shape == (96, 191)
    assert st["ci_max"] > 2 * st["ci_min"] + 1, "no time heterogeneity"
    assert st["nk_min"] >= 2 and st["nk_max"] <= 30
    assert st["nk_max"] >= 1.5 * st["nk_min"], "no satellite heterogeneity"


def test_higher_elevation_less_connectivity(small_spec):
    import dataclasses
    lo = CN.connectivity_sets(small_spec, days=0.25)
    hi_spec = dataclasses.replace(small_spec, min_elevation_deg=70.0)
    hi = CN.connectivity_sets(hi_spec, days=0.25)
    assert hi.sum() < lo.sum()
