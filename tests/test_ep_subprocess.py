"""Runs the expert-parallel shard_map MoE path on 8 virtual devices in a
fresh subprocess (XLA device count locks at first jax init, so the main
test process can't host it)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, dataclasses, numpy as np
import sys
sys.path.insert(0, "src")
from repro.configs.base import get_config
from repro.models import moe as M
cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                          moe_capacity_factor=8.0)
p = M.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, cfg.d_model))
mesh = jax.make_mesh((4, 2), ("data", "model"))
with mesh:
    y, _ = jax.jit(lambda p_, x_: M.moe_apply_ep(p_, x_, cfg, mesh))(p, x)
    g = jax.jit(jax.grad(
        lambda p_: jnp.sum(M.moe_apply_ep(p_, x, cfg, mesh)[0] ** 2)))(p)
ref = M.moe_apply_dense(p, x, cfg)
err = float(jnp.max(jnp.abs(y - ref)))
assert err < 3e-5, err
assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g))
print("EP_OK", err)
"""


def test_moe_ep_on_8_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "EP_OK" in r.stdout
