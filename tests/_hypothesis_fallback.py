"""Minimal deterministic stand-in for `hypothesis`, used only when the real
package is not installed (tests/conftest.py injects this module as
``sys.modules["hypothesis"]``).

It implements just the surface our property tests use — `given`,
`settings`, and the `integers` / `floats` / `booleans` / `lists` /
`composite` strategies — driving each test with a fixed-seed RNG instead
of shrinking search. Coverage is weaker than real hypothesis, but the
invariant checks still run everywhere (e.g. a fresh container without
optional dev deps).
"""
from __future__ import annotations

import types

import numpy as np

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng):
        return self._sample(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value,
                                                  max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def lists(elements, min_size=0, max_size=10):
    def _sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(_sample)


def tuples(*elements):
    return _Strategy(lambda rng: tuple(e.sample(rng) for e in elements))


class _Data:
    """Stand-in for the object `st.data()` hands to tests: `draw` samples
    a strategy against the run's RNG (labels accepted and ignored)."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.sample(self._rng)


def data():
    return _Strategy(lambda rng: _Data(rng))


def composite(fn):
    """`@st.composite def s(draw, ...): ...` -> calling s() returns a
    strategy that runs fn with a draw bound to the run's RNG."""
    def make(*args, **kwargs):
        def _sample(rng):
            return fn(lambda strat: strat.sample(rng), *args, **kwargs)
        return _Strategy(_sample)
    return make


def given(*strategies):
    def deco(fn):
        # zero-arg wrapper (not functools.wraps): pytest must not mistake
        # the wrapped function's drawn parameters for fixtures
        def wrapper():
            rng = np.random.default_rng(0)
            for _ in range(wrapper._max_examples):
                fn(*[s.sample(rng) for s in strategies])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._max_examples = DEFAULT_MAX_EXAMPLES
        return wrapper
    return deco


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_):
    def deco(fn):
        if hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn
    return deco


strategies = types.SimpleNamespace(
    integers=integers, floats=floats, booleans=booleans, lists=lists,
    tuples=tuples, data=data, composite=composite)
