"""Fault-injection layer tests (repro.core.faults): config validation,
trace resolution semantics, the pure masking transforms, the `fault_reset`
re-entry transition, and the engine-level contracts — `faults=None` (and a
trivial all-alive trace) bit-identical to the fault-free protocol, fast
and host execution strategies in lockstep under arbitrary churn, forced
re-download on recovery, dead satellites excluded from ISL participation,
and the blind/oracle scheduler plan-view split."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import faults as FT
from repro.core import isl as ISL
from repro.core import staleness as SS
from repro.core.isl import ISLConfig
from repro.fl.api import (ConstellationConfig, DatasetConfig, FaultConfig,
                          Federation, FLExperiment, LinkConfig,
                          SchedulerConfig)
from repro.fl.engine import EngineConfig, SimulationEngine
from tests.test_protocol_lockstep import (ScriptedScheduler, _budget,
                                          _StubAdapter, _linked_scenario,
                                          _scenario)


# ---------------------------------------------------------------- validation


@pytest.mark.parametrize("kw,field", [
    (dict(deorbit=((-1, 3),)), "deorbit"),
    (dict(deorbit=((2, -1),)), "deorbit"),
    (dict(launch=((-2, 0),)), "launch"),
    (dict(outages=((-1, 0, 4),)), "outages"),
    (dict(outages=((0, 5, 2),)), "outages"),
    (dict(rate_scale_min=-0.1), "rate_scale_min"),
    (dict(rate_scale_min=0.9, rate_scale_max=0.5), "rate_scale_min"),
    (dict(rate_block=0), "rate_block"),
])
def test_fault_config_validation_names_field(kw, field):
    with pytest.raises(ValueError, match=f"FaultConfig.{field}"):
        FaultConfig(**kw)


@pytest.mark.parametrize("kw,field", [
    (dict(uplink_topk=-0.5), "uplink_topk"),
    (dict(uplink_mbps=-1.0), "uplink_mbps"),
    (dict(downlink_mbps=-1.0), "downlink_mbps"),
    (dict(model_mb=-3.0), "model_mb"),
    (dict(gs_capacity=-1), "gs_capacity"),
])
def test_link_config_validation_names_field(kw, field):
    with pytest.raises(ValueError, match=f"LinkConfig.{field}"):
        LinkConfig(**kw)


@pytest.mark.parametrize("kw,field", [
    (dict(isl_mbps=-1.0), "isl_mbps"),
    (dict(model_mb=-1.0), "model_mb"),
    (dict(epoch=0), "epoch"),
])
def test_isl_config_validation_names_field(kw, field):
    with pytest.raises(ValueError, match=f"ISLConfig.{field}"):
        ISLConfig(**kw)


def test_trivial_config_detection():
    assert FaultConfig().trivial
    assert not FaultConfig(deorbit=((0, 1),)).trivial
    assert not FaultConfig(rate_scale_min=0.5).trivial


# ------------------------------------------------------------------- traces


def test_trace_deorbit_launch_semantics():
    cfg = FaultConfig(deorbit=((1, 4),), launch=((1, 8), (2, 3)))
    tr = FT.fault_trace(cfg, 12, K=4)
    # sat 1: deorbit first -> alive until 4, dead [4, 8), alive from 8
    assert tr.alive[:4, 1].all() and not tr.alive[4:8, 1].any() \
        and tr.alive[8:, 1].all()
    # sat 2: first event is a launch -> starts the run dead
    assert not tr.alive[:3, 2].any() and tr.alive[3:, 2].all()
    # untouched satellites alive throughout
    assert tr.alive[:, 0].all() and tr.alive[:, 3].all()
    # revive marks exactly the dead->alive edges (row 0 never revives)
    rv = tr.revive
    assert not rv[0].any()
    assert rv[8, 1] and rv[3, 2] and rv.sum() == 2


def test_trace_station_outage_and_weather():
    cfg = FaultConfig(outages=((1, 2, 5),), rate_scale_min=0.25,
                      rate_scale_max=0.75, rate_block=4, seed=9)
    tr = FT.fault_trace(cfg, 10, K=3, num_stations=2)
    assert tr.station_up[:, 0].all()
    assert tr.station_up[:2, 1].all() and not tr.station_up[2:5, 1].any() \
        and tr.station_up[5:, 1].all()
    # weather: blockwise-constant, within bounds, deterministic in seed
    assert (tr.rate_scale >= 0.25).all() and (tr.rate_scale <= 0.75).all()
    assert len(set(tr.rate_scale[:4])) == 1
    tr2 = FT.fault_trace(cfg, 10, K=3, num_stations=2)
    np.testing.assert_array_equal(tr.rate_scale, tr2.rate_scale)


def test_trace_validation_errors():
    with pytest.raises(ValueError, match="out of range"):
        FT.fault_trace(FaultConfig(deorbit=((7, 1),)), 5, K=4)
    with pytest.raises(ValueError, match="out of range"):
        FT.fault_trace(FaultConfig(outages=((3, 0, 2),)), 5, K=4,
                       num_stations=2)
    with pytest.raises(ValueError, match="station information"):
        FT.fault_trace(FaultConfig(outages=((0, 0, 2),)), 5, K=4)


def test_trace_reach_from_counts():
    # sat 0 only sees station 0, sat 1 only station 1; station 1 down
    # throughout -> sat 1 unreachable, sat 0 untouched
    counts = np.zeros((4, 2, 2), np.int32)
    counts[:, 0, 0] = 3
    counts[:, 1, 1] = 3
    cfg = FaultConfig(outages=((1, 0, 4),))
    tr = FT.fault_trace(cfg, 4, K=2, counts=counts)
    assert tr.reach[:, 0].all() and not tr.reach[:, 1].any()
    assert tr.mask[:, 0].all() and not tr.mask[:, 1].any()


def test_trace_extended_persists_final_row():
    cfg = FaultConfig(deorbit=((0, 2),), outages=((0, 1, 10),),
                      rate_scale_min=0.5, rate_scale_max=0.5)
    tr = FT.fault_trace(cfg, 4, K=2, num_stations=1).extended(9)
    assert tr.num_windows == 9
    assert not tr.alive[4:, 0].any()          # deorbited stays dead
    assert not tr.station_up[4:, 0].any()     # tail outage stays dark
    assert (tr.rate_scale[4:] == tr.rate_scale[3]).all()
    assert tr.extended(5) is tr               # no-op when already covered


# --------------------------------------------------------------- transforms


def test_mask_connectivity_kills_dead_contacts():
    C = np.ones((6, 3), bool)
    tr = FT.fault_trace(FaultConfig(deorbit=((1, 2),)), 6, K=3)
    M = FT.mask_connectivity(C, tr)
    assert M[:2].all() and not M[2:, 1].any() and M[2:, [0, 2]].all()


def test_mask_served_assigned_station_down_no_rebid():
    # both satellites visible to the up station too, but satellite 1 is
    # *assigned* to the down station -> its contact dies, no reassignment
    served = np.ones((2, 2), bool)
    grants = np.full((2, 2), 4, np.int32)
    assign = np.array([[0, 1], [0, 1]], np.int32)
    cfg = FaultConfig(outages=((1, 0, 2),), rate_scale_min=0.5,
                      rate_scale_max=0.5)
    tr = FT.fault_trace(cfg, 2, K=2, num_stations=2)
    s2, g2 = FT.mask_served(served, grants, assign, tr)
    assert s2[:, 0].all() and not s2[:, 1].any()
    np.testing.assert_array_equal(g2[:, 0], [2, 2])   # floor(4 * 0.5)
    np.testing.assert_array_equal(g2[:, 1], [0, 0])


def test_mask_budget_clears_assign_and_masks_visible():
    from repro.core.connectivity import LinkBudget
    b = LinkBudget(visible=np.ones((3, 2), bool),
                   served=np.ones((3, 2), bool),
                   assign=np.zeros((3, 2), np.int32),
                   grants=np.full((3, 2), 5, np.int32),
                   need_up=2, need_dn=1)
    tr = FT.fault_trace(FaultConfig(deorbit=((0, 1),)), 3, K=2,
                        num_stations=1)
    m = FT.mask_budget(b, tr)
    assert not m.visible[1:, 0].any() and m.visible[:, 1].all()
    assert not m.served[1:, 0].any()
    assert (m.assign[1:, 0] == -1).all() and (m.assign[:, 1] == 0).all()
    assert (m.grants[1:, 0] == 0).all()
    assert m.need_up == 2 and m.need_dn == 1   # costs never rescale


def test_fault_reset_semantics_and_idempotency():
    state = SS.SatState(jnp.array([3, 4]), jnp.array([2, -1]),
                        jnp.array([1, 0]), jnp.array([5, 6]),
                        jnp.array([7, 8]))
    revive = jnp.array([True, False])
    out = FT.fault_reset(state, revive)
    assert int(out.version[0]) == -1 and int(out.pending[0]) == -1
    assert int(out.progress[0]) == 0 and int(out.relay[0]) == 0
    # untouched columns / satellites
    np.testing.assert_array_equal(np.asarray(out.buffered), [1, 0])
    assert int(out.version[1]) == 4 and int(out.progress[1]) == 6
    again = FT.fault_reset(out, revive)
    for a, b in zip(out, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scenario_helpers_deterministic():
    churn = FT.random_churn(20, 50, 0.25, seed=4)
    assert churn == FT.random_churn(20, 50, 0.25, seed=4)
    assert len(churn) == 5
    assert len({k for k, _ in churn}) == 5          # distinct satellites
    assert all(1 <= w < 50 for _, w in churn)
    assert FT.random_churn(20, 50, 0.0) == ()
    bo = FT.station_blackout(3, 4, 9)
    assert bo == ((0, 4, 9), (1, 4, 9), (2, 4, 9))


# -------------------------------------------------- ISL fault interactions


def test_gossip_step_ignores_dead_satellites():
    K = 4
    idx = jnp.arange(K, dtype=jnp.int32)
    nxt = jnp.asarray((np.arange(K) + 1) % K, jnp.int32)
    prv = jnp.asarray((np.arange(K) - 1) % K, jnp.int32)
    state = SS.SatState(jnp.array([5, 0, 0, 0]), jnp.array([5, 0, 0, 0]),
                        jnp.full(K, -1))
    alive = jnp.array([False, True, True, True])
    st2, adopted = ISL.gossip_step(state, nxt, prv, idx, idx,
                                   jnp.bool_(True), alive=alive)
    # the dead satellite's newer version must not propagate, and the dead
    # satellite itself must not adopt
    np.testing.assert_array_equal(np.asarray(st2.version), [5, 0, 0, 0])
    assert not bool(adopted.any())
    # without the mask it would propagate to both ring neighbours
    st3, _ = ISL.gossip_step(state, nxt, prv, idx, idx, jnp.bool_(True))
    assert int(np.asarray(st3.version)[1]) == 5


def test_elect_sinks_skips_dead_candidates():
    topo = ISL.ISLTopology(plane=np.zeros(3, np.int32),
                           pos=np.arange(3, dtype=np.int32),
                           nxt=np.array([1, 2, 0], np.int32),
                           prv=np.array([2, 0, 1], np.int32),
                           left=np.arange(3, dtype=np.int32),
                           right=np.arange(3, dtype=np.int32))
    C = np.zeros((4, 3), bool)
    C[0, 0] = True       # satellite 0 has the earliest contact...
    C[2, 1] = True
    assert ISL.elect_sinks(C, topo)[0] == 0
    # ...but dead candidates are skipped
    sink = ISL.elect_sinks(C, topo, alive=np.array([False, True, True]))
    assert (sink == 1).all()
    # an all-dead plane falls back to the full membership
    sink = ISL.elect_sinks(C, topo, alive=np.zeros(3, bool))
    assert (sink == 0).all()


# -------------------------------------------- engine: parity and lockstep


def _all_alive_trace(I, K):
    return FT.fault_trace(FaultConfig(deorbit=((0, I + 1),)), I, K=K)


@st.composite
def _fault_events(draw, K, I):
    deorbit = draw(st.lists(
        st.tuples(st.integers(0, K - 1), st.integers(0, I)), max_size=3))
    launch = draw(st.lists(
        st.tuples(st.integers(0, K - 1), st.integers(0, I)), max_size=3))
    return FaultConfig(deorbit=tuple(deorbit), launch=tuple(launch))


@settings(max_examples=15, deadline=None)
@given(_scenario())
def test_all_alive_trace_is_bit_identical(scn):
    """A trace injecting nothing live (a deorbit beyond the horizon) must
    reproduce the faults=None trajectory bit-for-bit under both
    strategies — the faults=None parity contract."""
    C, a = scn
    I, K = C.shape
    ref = SimulationEngine(C, _StubAdapter(K), ScriptedScheduler(a),
                           EngineConfig(eval_every=I + 1))
    ref_res = ref.run()
    for fast in (True, False):
        eng = SimulationEngine(C, _StubAdapter(K),
                               ScriptedScheduler(a, device=fast),
                               EngineConfig(eval_every=I + 1,
                                            fast_loop=fast),
                               faults=_all_alive_trace(I, K))
        res = eng.run()
        np.testing.assert_array_equal(eng.version, ref.version)
        np.testing.assert_array_equal(eng.pending, ref.pending)
        np.testing.assert_array_equal(eng.buffered_base, ref.buffered_base)
        assert eng.ig == ref.ig
        assert res.summary() == ref_res.summary()


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_faulted_engine_strategies_lockstep(data):
    """Fast and host loops must stay bit-identical under arbitrary churn
    (the fault analogue of the protocol lockstep property)."""
    C, a = data.draw(_scenario())
    I, K = C.shape
    trace = FT.fault_trace(data.draw(_fault_events(K, I)), I, K=K)
    runs = []
    for fast in (True, False):
        eng = SimulationEngine(C, _StubAdapter(K),
                               ScriptedScheduler(a, device=fast),
                               EngineConfig(eval_every=I + 1,
                                            fast_loop=fast), faults=trace)
        res = eng.run()
        assert eng._fast_ok == fast
        runs.append((eng, res))
    (ef, rf), (eh, rh) = runs
    np.testing.assert_array_equal(ef.version, eh.version)
    np.testing.assert_array_equal(ef.pending, eh.pending)
    np.testing.assert_array_equal(ef.buffered_base, eh.buffered_base)
    assert ef.ig == eh.ig
    assert rf.summary() == rh.summary()
    assert rf.total_connections == rh.total_connections
    assert rf.idle_connections == rh.idle_connections
    # executed connections are the fault-masked ones
    assert rf.total_connections == int((C & trace.mask).sum())


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_faulted_linked_engine_strategies_lockstep(data):
    """Same lockstep property with a finite link budget in the loop (the
    masked-grants path)."""
    C, a, grants, need_up, need_dn = data.draw(_linked_scenario())
    I, K = C.shape
    cfg = data.draw(_fault_events(K, I))
    cfg = dataclasses.replace(cfg, rate_scale_min=0.5, rate_scale_max=1.0,
                              rate_block=4)
    trace = FT.fault_trace(cfg, I, K=K)
    runs = []
    for fast in (True, False):
        eng = SimulationEngine(C, _StubAdapter(K),
                               ScriptedScheduler(a, device=fast),
                               EngineConfig(eval_every=I + 1,
                                            fast_loop=fast),
                               link_budget=_budget(C, grants, need_up,
                                                   need_dn), faults=trace)
        res = eng.run()
        runs.append((eng, res))
    (ef, rf), (eh, rh) = runs
    np.testing.assert_array_equal(ef.version, eh.version)
    np.testing.assert_array_equal(ef.pending, eh.pending)
    np.testing.assert_array_equal(ef.buffered_base, eh.buffered_base)
    np.testing.assert_array_equal(ef.transfer_progress,
                                  eh.transfer_progress)
    assert ef.ig == eh.ig
    assert rf.summary() == rh.summary()


def test_recovered_satellite_forced_redownload():
    """A satellite that dies and revives comes back as "never received":
    until its next (post-revival) contact it holds version/pending -1 and
    cannot upload a pre-outage update."""
    I, K = 8, 2
    C = np.zeros((I, K), bool)
    C[:, 0] = True           # satellite 0: control, always connected
    C[0, 1] = True           # satellite 1 uploads at window 0...
    a = np.zeros(I, np.int32)
    a[1] = 1                 # ...aggregation at window 1
    trace = FT.fault_trace(
        FaultConfig(deorbit=((1, 2),), launch=((1, 5),)), I, K=K)
    for fast in (True, False):
        eng = SimulationEngine(C, _StubAdapter(K),
                               ScriptedScheduler(a, device=fast),
                               EngineConfig(eval_every=I + 1,
                                            fast_loop=fast), faults=trace)
        eng.run()
        # revived at 5 with no further contact: state is the reset state,
        # not the pre-outage (version 0 / fresh-round) state
        assert eng.version[1] == -1 and eng.pending[1] == -1
        assert eng.version[0] == eng.ig == 1


class _ProbeScheduler(ScriptedScheduler):
    """Records which connectivity object `device_plan` receives."""

    def __init__(self, a):
        super().__init__(a, device=True)
        self.seen = []

    def device_plan(self, i, *, connectivity, **kw):
        self.seen.append((connectivity, kw.get("exec_connectivity")))
        return super().device_plan(i)


def test_blind_vs_oracle_plan_view():
    I, K = 8, 3
    C = np.ones((I, K), bool)
    a = np.zeros(I, np.int32)
    cfg = FaultConfig(deorbit=((0, 2),))
    for oracle in (False, True):
        trace = FT.fault_trace(dataclasses.replace(cfg, oracle=oracle),
                               I, K=K)
        sched = _ProbeScheduler(a)
        eng = SimulationEngine(C, _StubAdapter(K), sched,
                               EngineConfig(eval_every=I + 1), faults=trace)
        eng.run()
        plan_c, exec_c = sched.seen[0]
        assert np.array_equal(exec_c, eng.C)
        assert exec_c[3, 0] == False  # noqa: E712 — executed world faulted
        if oracle:
            assert np.array_equal(plan_c, eng.C)       # planner sees faults
        else:
            assert plan_c[3, 0] and plan_c.all()       # planner stays clean


# --------------------------------------------------------- Federation wiring


def _tiny_experiment(**kw):
    return FLExperiment(
        constellation=ConstellationConfig(num_satellites=8, days=0.5),
        dataset=DatasetConfig(num_train=64, num_val=32),
        scheduler=SchedulerConfig(kind="async"),
        train=EngineConfig(local_steps=1, eval_every=16, max_windows=16),
        **kw)


def test_federation_trivial_faults_resolve_to_none():
    fed = Federation.from_experiment(_tiny_experiment(faults=FaultConfig()))
    assert fed.faults is None
    assert fed.engine().faults is None


def test_federation_resolves_and_shares_fault_trace():
    cfg = FaultConfig(deorbit=((1, 3),), outages=((0, 0, 8),))
    fed = Federation.from_experiment(_tiny_experiment(faults=cfg))
    assert isinstance(fed.faults, FT.FaultTrace)
    assert fed.faults.alive.shape == fed.C.shape
    # geometry path + outages: the reach mask was resolved from counts
    assert fed.faults.reach is not None
    # with_scheduler clones share the identical resolved trace (one fault
    # world across a scheduler comparison)
    assert fed.with_scheduler("sync").faults is fed.faults
    eng = fed.engine()
    assert eng.faults is fed.faults
    assert not eng.C[4:, 1].any()     # dead satellite lost its contacts


def test_federation_linked_faults_mask_grants():
    cfg = FaultConfig(deorbit=((0, 1),), rate_scale_min=0.5,
                      rate_scale_max=0.5)
    link = LinkConfig(uplink_mbps=10.0, downlink_mbps=10.0, model_mb=40.0,
                      gs_capacity=1)
    fed = Federation.from_experiment(
        _tiny_experiment(faults=cfg, link=link))
    eng = fed.engine()
    eng.prepare()
    assert not eng.C[1:, 0].any()                    # dead: no service
    clean = eng._plan_grants
    # surviving grants are the weather-scaled clean grants
    served = eng.C
    np.testing.assert_array_equal(
        eng._grants[served], (clean[served] * 0.5).astype(np.int32))
    # blind by default: schedulers plan on the clean artifacts
    assert eng._plan_C is not eng.C
    assert eng._plan_link.grant is clean
