"""Lockstep property tests: the FL engine and the schedule-search
simulator must traverse identical protocol state over the same random
connectivity + schedule — the invariant the unified Algorithm-1 transition
layer (repro.core.staleness sub-transitions) rests on. Driven through both
engine strategies: the chunked device fast loop and the per-window host
loop — with and without link-budget transfer gating, and with the
trivial (infinite-capacity / zero-latency) budget required to be
bit-identical to the geometry-only path."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import staleness as SS
from repro.core.connectivity import LinkBudget
from repro.core.scheduler import Scheduler
from repro.fl.engine import EngineConfig, SimulationEngine


class _StubAdapter:
    """Minimal adapter: tiny model, zero-gradient loss — client training
    is a no-op, so runs isolate the protocol dynamics."""

    def __init__(self, K):
        self.clients = list(range(K))

    def init(self, key):
        return {"w": jnp.zeros((2,))}

    def loss(self, params, batch):
        return jnp.sum(params["w"]) * 0.0 + jnp.sum(batch) * 0.0

    def client_batch(self, ci, round_rng, batch_size, num_batches):
        return jnp.zeros((num_batches, 1))

    def accuracy(self, params):
        return 0.0

    def val_loss(self, params):
        return 0.0


def _scripted_indicator(t, n_buf, args):
    return args[t] > 0


class ScriptedScheduler(Scheduler):
    """Replays a fixed schedule a^i — the engine-side mirror of feeding
    the same `a` to `simulate_window`. `device=True` additionally offers
    the schedule as a device plan, putting the engine on the chunked
    fast loop."""
    name = "scripted"

    def __init__(self, a, device=True):
        self.a = np.asarray(a, np.int32)
        self._device = device

    def decide(self, i, *, n_in_buffer, **_):
        return bool(self.a[i]) and n_in_buffer > 0

    def device_plan(self, i, **_):
        if not self._device:
            return None
        return _scripted_indicator, jnp.asarray(self.a), None


@st.composite
def _scenario(draw):
    K = draw(st.integers(2, 8))
    I = draw(st.integers(4, 24))
    C = np.array(draw(st.lists(st.lists(st.booleans(), min_size=K,
                                        max_size=K), min_size=I,
                               max_size=I)), bool)
    a = np.array(draw(st.lists(st.integers(0, 1), min_size=I, max_size=I)),
                 np.int32)
    return C, a


@st.composite
def _linked_scenario(draw):
    """A scenario plus a finite link budget: random per-window grants and
    small unit needs, so transfers span several contact windows."""
    C, a = draw(_scenario())
    I, K = C.shape
    grants = np.array(draw(st.lists(st.lists(st.integers(1, 3), min_size=K,
                                             max_size=K), min_size=I,
                                    max_size=I)), np.int32) * C
    need_up = draw(st.integers(0, 4))
    need_dn = draw(st.integers(0, 4))
    return C, a, grants, need_up, need_dn


def _budget(C, grants, need_up, need_dn):
    """Synthetic LinkBudget over an already-resolved connectivity matrix
    (contention folded into `grants`/`C` by construction)."""
    return LinkBudget(visible=C, served=C,
                      assign=np.where(C, 0, -1).astype(np.int32),
                      grants=grants, need_up=need_up, need_dn=need_dn)


@settings(max_examples=15, deadline=None)
@given(_scenario())
def test_engine_steps_lockstep_with_simulator(scn):
    """Per-window host loop vs `SS.step`, compared after EVERY window:
    identical SatState, global version, idle count, and staleness
    histogram."""
    C, a = scn
    I, K = C.shape
    eng = SimulationEngine(C, _StubAdapter(K),
                           ScriptedScheduler(a, device=False),
                           EngineConfig(eval_every=I + 1, fast_loop=False))
    eng.prepare()
    state, ig = SS.bootstrap_state(K), jnp.int32(0)
    idle, hist = 0, np.zeros(eng.config.s_max + 1, np.int64)
    for i in range(I):
        conn = C[i]
        n_buf = eng.on_uploads(i, conn)
        if eng.on_decide(i, n_buf) and n_buf > 0:
            eng.on_aggregate(i)
        eng.on_downloads(i, conn)
        state, ig, info = SS.step(state, ig, jnp.asarray(conn),
                                  jnp.asarray(bool(a[i])),
                                  s_max=eng.config.s_max)
        idle += int(info["n_idle"])
        hist += np.asarray(info["hist"])
        np.testing.assert_array_equal(eng.version,
                                      np.asarray(state.version)), i
        np.testing.assert_array_equal(eng.pending,
                                      np.asarray(state.pending)), i
        np.testing.assert_array_equal(eng.buffered_base,
                                      np.asarray(state.buffered)), i
        assert eng.ig == int(ig), i
    assert eng.result.idle_connections == idle
    assert eng.result.staleness_hist.tolist() == hist.tolist()


@settings(max_examples=15, deadline=None)
@given(_scenario())
def test_engine_run_matches_simulate_window(scn):
    """Full runs through both execution strategies land on exactly the
    state/counters `simulate_window` computes for the same schedule."""
    C, a = scn
    I, K = C.shape
    state, ig, infos = SS.simulate_window(
        jnp.asarray(C), jnp.asarray(a), SS.bootstrap_state(K),
        jnp.int32(0))
    for fast in (True, False):
        eng = SimulationEngine(C, _StubAdapter(K),
                               ScriptedScheduler(a, device=fast),
                               EngineConfig(eval_every=I + 1,
                                            fast_loop=fast))
        res = eng.run()
        assert eng._fast_ok == fast
        np.testing.assert_array_equal(eng.version,
                                      np.asarray(state.version))
        np.testing.assert_array_equal(eng.pending,
                                      np.asarray(state.pending))
        np.testing.assert_array_equal(eng.buffered_base,
                                      np.asarray(state.buffered))
        assert eng.ig == int(ig)
        assert res.total_connections == int(C.sum())
        assert res.idle_connections == \
            int(np.asarray(infos["n_idle"]).sum())
        assert res.num_aggregated_gradients == \
            int(np.asarray(infos["n_aggregated"]).sum())
        assert res.staleness_hist.tolist() == \
            np.asarray(infos["hist"]).sum(axis=0).tolist()


def _run_engine(C, a, *, fast, budget=None, **cfg):
    I, K = C.shape
    eng = SimulationEngine(C, _StubAdapter(K),
                           ScriptedScheduler(a, device=fast),
                           EngineConfig(eval_every=I + 1, fast_loop=fast,
                                        **cfg),
                           link_budget=budget)
    res = eng.run()
    assert eng._fast_ok == fast
    return eng, res


@settings(max_examples=15, deadline=None)
@given(_scenario())
def test_trivial_link_budget_is_bit_identical(scn):
    """The infinite-capacity / zero-latency budget (served == C, needs 0)
    must reproduce the geometry-only engine trajectory bit-for-bit under
    BOTH execution strategies — the parity the whole link-budget layer is
    gated on."""
    C, a = scn
    grants = np.ones(C.shape, np.int32) * C
    ref, ref_res = _run_engine(C, a, fast=True)
    for fast in (True, False):
        eng, res = _run_engine(C, a, fast=fast,
                               budget=_budget(C, grants, 0, 0))
        np.testing.assert_array_equal(eng.version, ref.version)
        np.testing.assert_array_equal(eng.pending, ref.pending)
        np.testing.assert_array_equal(eng.buffered_base, ref.buffered_base)
        assert eng.ig == ref.ig
        assert res.total_connections == ref_res.total_connections
        assert res.idle_connections == ref_res.idle_connections
        assert res.num_aggregated_gradients == \
            ref_res.num_aggregated_gradients
        assert res.staleness_hist.tolist() == \
            ref_res.staleness_hist.tolist()
        assert eng.transfer_progress.max() == 0   # nothing ever in flight


@settings(max_examples=15, deadline=None)
@given(_linked_scenario())
def test_linked_engine_run_matches_simulate_window(scn):
    """Under a finite link budget, full engine runs through both
    strategies land exactly on the state/counters the link-gated
    `simulate_window` computes — the invariant that lets the eq.-13 search
    score candidates against effective connectivity."""
    C, a, grants, need_up, need_dn = scn
    I, K = C.shape
    gate = SS.LinkGate(jnp.asarray(grants), jnp.int32(need_up),
                       jnp.int32(need_dn))
    state, ig, infos = SS.simulate_window(
        jnp.asarray(C), jnp.asarray(a),
        SS.bootstrap_state(K, progress=True), jnp.int32(0), link=gate)
    for fast in (True, False):
        eng, res = _run_engine(C, a, fast=fast,
                               budget=_budget(C, grants, need_up, need_dn))
        np.testing.assert_array_equal(eng.version,
                                      np.asarray(state.version))
        np.testing.assert_array_equal(eng.pending,
                                      np.asarray(state.pending))
        np.testing.assert_array_equal(eng.buffered_base,
                                      np.asarray(state.buffered))
        np.testing.assert_array_equal(eng.transfer_progress,
                                      np.asarray(state.progress))
        assert eng.ig == int(ig)
        assert res.total_connections == int(C.sum())
        assert res.idle_connections == \
            int(np.asarray(infos["n_idle"]).sum())
        assert res.num_aggregated_gradients == \
            int(np.asarray(infos["n_aggregated"]).sum())
        assert res.staleness_hist.tolist() == \
            np.asarray(infos["hist"]).sum(axis=0).tolist()
