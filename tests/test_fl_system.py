"""End-to-end FL system tests: data partitioning, simulation semantics,
optimizers, checkpointing, aggregation (eq. 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import connectivity as CN
from repro.core.aggregation import aggregation_weights, apply_aggregation
from repro.core.scheduler import make_scheduler
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import (iid_partition, noniid_partition,
                                  partition_stats)
from repro.data.pipeline import make_clients
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.simulation import run_simulation
from repro.optim import (adamw_init, adamw_update, apply_updates,
                         clip_by_global_norm, sgd_init, sgd_update)
from repro.ckpt.checkpoint import (CheckpointStore, DeviceCheckpointStore,
                                   load_pytree, save_pytree)


@pytest.fixture(scope="module")
def small_world():
    spec = CN.ConstellationSpec(num_satellites=24)
    C = CN.connectivity_sets(spec, days=1.0)
    data = SyntheticFmow(FmowSpec(num_train=2400, num_val=600))
    parts = iid_partition(2400, 24, 0)
    adapter = MlpFmowAdapter(data, make_clients(parts))
    return spec, C, data, adapter


# ---------------------------------------------------------------------------
# data


def test_iid_partition_exact_cover():
    parts = iid_partition(1000, 7, 0)
    allidx = np.sort(np.concatenate(parts))
    assert (allidx == np.arange(1000)).all()


def test_noniid_partition_cover_and_skew(small_world):
    spec, _, data, _ = small_world
    parts = noniid_partition(data.train_zones, 24, spec, days=1.0)
    allidx = np.sort(np.concatenate(parts))
    assert (allidx == np.arange(data.spec.num_train)).all()
    st_iid = partition_stats(iid_partition(data.spec.num_train, 24, 0),
                             data.train_labels)
    st_non = partition_stats(parts, data.train_labels)
    assert st_non["tv_mean"] > st_iid["tv_mean"] + 0.05, \
        "non-IID partition is not skewed vs IID"


# ---------------------------------------------------------------------------
# optimizers / checkpoint


def test_sgd_matches_manual(key):
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 0.5)}
    upd, st = sgd_update(g, sgd_init(p), p, lr=0.1)
    p2 = apply_updates(p, upd)
    np.testing.assert_allclose(np.asarray(p2["w"]), 1.0 - 0.05, rtol=1e-6)


def test_adamw_converges_quadratic(key):
    p = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(p)
    for _ in range(300):
        g = {"w": 2 * p["w"]}
        upd, opt = adamw_update(g, opt, p, lr=0.05, weight_decay=0.0)
        p = apply_updates(p, upd)
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    n2 = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    np.testing.assert_allclose(float(n2), 1.0, rtol=1e-5)


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"a": jax.random.normal(key, (4, 5)),
            "b": [jnp.arange(3), {"c": jnp.float32(2.5)}]}
    path = str(tmp_path / "ck.npz")
    save_pytree(path, tree)
    back = load_pytree(path, tree)
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y)), tree, back)


def test_checkpoint_store_prune():
    st = CheckpointStore(keep_in_memory=3)
    for v in range(8):
        st.put(v, {"w": jnp.full((2,), float(v))})
    st.prune(min_referenced=6)
    assert 6 in st._mem and 7 in st._mem
    with pytest.raises(KeyError):
        st.get(0)


@pytest.mark.parametrize("cls,kw", [
    (CheckpointStore, {"keep_in_memory": 2}),
    (DeviceCheckpointStore, {"ring": 2}),
])
def test_checkpoint_store_prune_unlinks_disk_spill(tmp_path, cls, kw):
    """Regression: prune used to leave spilled .npz files (and `_disk`
    entries) behind forever, growing disk unboundedly on long runs."""
    st = cls(directory=str(tmp_path), spill_every=1, **kw)
    for v in range(10):
        st.put(v, {"w": jnp.full((2,), float(v))})
    assert len(list(tmp_path.glob("*.npz"))) == 10
    st.prune(min_referenced=9)       # cutoff = newest - keep + 1 = 8
    assert sorted(st._disk) == [8, 9]
    assert sorted(p.name for p in tmp_path.glob("*.npz")) == \
        ["w_000008.npz", "w_000009.npz"]


def test_device_checkpoint_store_contract():
    """Ring hits return device arrays with the put values; ring-evicted
    versions spill to host and stay readable until pruned; `get_many`
    gathers a stacked pytree; misses raise the same KeyError contract."""
    st = DeviceCheckpointStore(ring=4)
    for v in range(9):
        st.put(v, {"w": jnp.full((3,), float(v)), "b": jnp.arange(2) + v})
    assert st.versions() == list(range(9))
    for v in range(9):                        # 5..8 in ring, 0..4 spilled
        got = st.get(v)
        assert isinstance(got["w"], jax.Array)
        assert float(got["w"][0]) == v and int(got["b"][1]) == v + 1
    stacked = st.get_many([6, 8, 5])
    assert np.asarray(stacked["w"])[:, 0].tolist() == [6.0, 8.0, 5.0]
    st.prune(min_referenced=7)       # cutoff = min(7, newest - ring + 1)
    assert st.versions() == [5, 6, 7, 8]
    with pytest.raises(KeyError):
        st.get(4)


def test_device_checkpoint_store_overwrites_in_place():
    """Re-putting a version replaces the slot content (no stale host
    copy resurfacing)."""
    st = DeviceCheckpointStore(ring=3)
    st.put(0, {"w": jnp.zeros(2)})
    st.put(0, {"w": jnp.ones(2)})
    assert float(st.get(0)["w"][0]) == 1.0
    assert st.versions() == [0]


# ---------------------------------------------------------------------------
# aggregation (eq. 4)


def test_aggregation_weights_normalized():
    w = aggregation_weights(jnp.asarray([0, 1, 4, 8]), alpha=0.5)
    np.testing.assert_allclose(float(jnp.sum(w)), 1.0, rtol=1e-6)
    assert float(w[0]) > float(w[3])     # fresher => heavier


def test_apply_aggregation_matches_manual(key):
    params = {"w": jnp.zeros((5,))}
    upds = {"w": jnp.stack([jnp.ones(5), 2 * jnp.ones(5)])}
    stal = jnp.asarray([0, 1])
    out = apply_aggregation(params, upds, stal, alpha=1.0)
    c = np.array([1.0, 0.5])
    expect = (c / c.sum()) @ np.stack([np.ones(5), 2 * np.ones(5)])
    np.testing.assert_allclose(np.asarray(out["w"]), expect, rtol=1e-5)


def test_apply_aggregation_kernel_path_matches(key):
    params = {"w": jax.random.normal(key, (3, 7)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (11,))}
    upds = jax.tree.map(
        lambda p: jax.random.normal(jax.random.fold_in(key, 2),
                                    (4,) + p.shape), params)
    stal = jnp.asarray([0, 1, 2, 3])
    a = apply_aggregation(params, upds, stal)               # jnp off-TPU
    b = apply_aggregation(params, upds, stal, interpret=True)   # kernel

    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x), np.asarray(y), atol=1e-5), a, b)


# ---------------------------------------------------------------------------
# simulation semantics


def test_sync_zero_staleness(small_world):
    _, C, _, adapter = small_world
    res = run_simulation(C, adapter, make_scheduler("sync"), eval_every=24,
                         max_windows=96)
    assert res.staleness_hist[1:].sum() == 0
    assert res.num_global_updates >= 1


def test_async_no_idle(small_world):
    _, C, _, adapter = small_world
    res = run_simulation(C, adapter, make_scheduler("async"), eval_every=24,
                         max_windows=96)
    assert res.idle_connections == 0
    assert res.staleness_hist.sum() == res.num_aggregated_gradients


def test_fedbuff_buffer_threshold(small_world):
    _, C, _, adapter = small_world
    res = run_simulation(C, adapter, make_scheduler("fedbuff", M=8),
                         eval_every=24, max_windows=96)
    # every aggregation consumed >= M gradients
    assert res.num_aggregated_gradients >= 8 * res.num_global_updates


def test_learning_happens(small_world):
    _, C, _, adapter = small_world
    res = run_simulation(C, adapter, make_scheduler("fedbuff", M=8),
                         eval_every=16, max_windows=96)
    assert res.accuracy[-1] > 2.0 / 62.0, "no learning signal"
