"""Per-kernel validation (deliverable c): shape/dtype sweeps asserting
allclose against the pure-jnp oracles, interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.agg.kernel import weighted_aggregate
from repro.kernels.agg.ops import aggregate_params_tree, \
    weighted_aggregate_tree
from repro.kernels.agg.ref import weighted_aggregate_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# aggregation kernel


@pytest.mark.parametrize("m", [1, 7, 64, 191])
@pytest.mark.parametrize("n", [128, 5000, 40_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_agg_sweep(m, n, dtype, key):
    upd = jax.random.normal(key, (m, n), dtype)
    p = jax.random.normal(jax.random.fold_in(key, 1), (n,), dtype)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (m,), jnp.float32)
    w = w / w.sum()
    out = weighted_aggregate(p, upd, w, block=4096, interpret=True)
    ref = weighted_aggregate_ref(p, upd, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_agg_tree_paths(key):
    tree = {"a": jax.random.normal(key, (5, 16, 8)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                         (5, 33))}}
    w = jnp.asarray([0.5, 0.2, 0.1, 0.1, 0.1])
    got = weighted_aggregate_tree(tree, w, interpret=True)
    ref = jax.tree.map(lambda u: jnp.tensordot(w, u, axes=1), tree)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5), got, ref)

    params = jax.tree.map(lambda u: u[0], tree)
    got2 = aggregate_params_tree(params, tree, w, interpret=True)
    ref2 = jax.tree.map(lambda p, d: p + d, params, ref)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5), got2, ref2)


# ---------------------------------------------------------------------------
# rmsnorm kernel


@pytest.mark.parametrize("shape", [(4, 128), (3, 5, 256), (37, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype, key):
    x = jax.random.normal(key, shape, dtype)
    s = jax.random.normal(jax.random.fold_in(key, 1), (shape[-1],), dtype)
    out = rmsnorm(x, s, rows=8, interpret=True)
    ref = rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# flash attention kernel


@pytest.mark.parametrize("h,k", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 32),
                                           (False, 0)])
def test_flash_gqa_mask_sweep(h, k, causal, window, key):
    B, S, hd = 2, 128, 64
    q = jax.random.normal(key, (B, h, S, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, k, S, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, k, S, hd))
    out = flash_attention(q, kk, vv, causal=causal, window=window, bq=32,
                          bk=32, interpret=True)
    ref = attention_ref(q, kk, vv, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("sq,sk", [(64, 64), (100, 200), (64, 192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_shape_dtype_sweep(sq, sk, dtype, key):
    B, H, hd = 1, 2, 128
    q = jax.random.normal(key, (B, H, sq, hd), dtype)
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, H, sk, hd), dtype)
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, H, sk, hd), dtype)
    out = flash_attention(q, kk, vv, causal=False, bq=32, bk=64,
                          interpret=True)
    ref = attention_ref(q, kk, vv, causal=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_block_shape_invariance(key):
    """Output must not depend on the BlockSpec tiling."""
    B, H, S, hd = 1, 2, 256, 64
    q = jax.random.normal(key, (B, H, S, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, hd))
    outs = [flash_attention(q, kk, vv, causal=True, bq=bq, bk=bk,
                            interpret=True)
            for bq, bk in [(32, 32), (64, 128), (256, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# FL-payload shapes + ops-layer dispatch (the transformer adapter's hot
# path: tiny sequences, narrow heads — far off the LLM-shaped sweeps above)


def test_rmsnorm_fl_shape_parity(key):
    """TransformerFmowAdapter hidden states: (B, S, d_model) = (32, 8, 32)."""
    x = jax.random.normal(key, (32, 8, 32))
    s = jax.random.normal(jax.random.fold_in(key, 1), (32,))
    out = rmsnorm(x, s, rows=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rmsnorm_ref(x, s)),
                               atol=2e-5)


def test_flash_fl_shape_parity(key):
    """Adapter attention shapes: B=32 clients*batch, H=4, K=2 (GQA),
    S=8 tokens, hd=8 — the kernel must clamp its tiles to the tiny
    sequence and still match the oracle."""
    B, H, K, S, hd = 32, 4, 2, 8, 8
    q = jax.random.normal(key, (B, H, S, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 1), (B, K, S, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 2), (B, K, S, hd))
    out = flash_attention(q, kk, vv, causal=True, bq=S, bk=S, interpret=True)
    ref = attention_ref(q, kk, vv, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_ops_dispatch_bit_identical_to_oracle_off_tpu(key):
    """`interpret=None` (the FL default) must BE the jnp oracle off-TPU —
    bit-identical, not allclose — so simulation trajectories through the
    transformer adapter stay reproducible on CPU CI."""
    from repro.kernels import on_tpu
    from repro.kernels.flash_attention.ops import flash_attention_bshd
    from repro.kernels.rmsnorm.ops import rmsnorm as rmsnorm_op
    if on_tpu():
        pytest.skip("off-TPU dispatch path")
    x = jax.random.normal(key, (32, 8, 32))
    s = jax.random.normal(jax.random.fold_in(key, 1), (32,))
    assert np.array_equal(np.asarray(rmsnorm_op(x, s)),
                          np.asarray(rmsnorm_ref(x, s)))
    B, H, K, S, hd = 4, 4, 2, 8, 8
    # ops layer takes the model's (B, S, H, hd) layout
    q = jax.random.normal(key, (B, S, H, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 3), (B, S, K, hd))
    got = flash_attention_bshd(q, kk, vv, causal=True)
    ref = jnp.moveaxis(attention_ref(jnp.moveaxis(q, 2, 1),
                                     jnp.moveaxis(kk, 2, 1),
                                     jnp.moveaxis(vv, 2, 1), causal=True),
                       1, 2)
    assert got.shape == q.shape
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_ops_interpret_true_close_to_oracle(key):
    """Explicit `interpret=True` routes through the Pallas interpreter:
    numerically close to — though not bit-identical with — the oracle."""
    from repro.kernels.flash_attention.ops import flash_attention_bshd
    from repro.kernels.rmsnorm.ops import rmsnorm as rmsnorm_op
    x = jax.random.normal(key, (16, 8, 32))
    s = jax.random.normal(jax.random.fold_in(key, 1), (32,))
    np.testing.assert_allclose(np.asarray(rmsnorm_op(x, s, interpret=True)),
                               np.asarray(rmsnorm_ref(x, s)), atol=2e-5)
    B, H, K, S, hd = 2, 4, 2, 8, 8
    q = jax.random.normal(key, (B, S, H, hd))
    kk = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
    vv = jax.random.normal(jax.random.fold_in(key, 3), (B, S, K, hd))
    got = flash_attention_bshd(q, kk, vv, causal=True, bq=S, bk=S,
                               interpret=True)
    ref = jnp.moveaxis(attention_ref(jnp.moveaxis(q, 2, 1),
                                     jnp.moveaxis(kk, 2, 1),
                                     jnp.moveaxis(vv, 2, 1), causal=True),
                       1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
