"""Sharding-rule properties and host-mesh execution of the pjit step
functions (the same code paths the 512-device dry-run lowers)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as Sh
from repro.configs.base import INPUT_SHAPES, ShapeConfig, get_config, \
    list_configs
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.optim import adamw_init

ARCHS = [a for a in list_configs() if a != "densenet-fl"]

def _fake_mesh():
    """Abstract 16x16 mesh for spec computation only (no devices needed) —
    `repro.core.mesh.abstract_mesh` bridges the AbstractMesh signature
    change across jax versions."""
    from repro.core.mesh import abstract_mesh
    return abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    """Every sharded dim must divide by its mesh axis size."""
    cfg = get_config(arch)
    mesh = _fake_mesh()
    shapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0),
                                                  cfg))
    specs = Sh.param_specs(shapes, cfg, mesh)

    def check(leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else \
                int(np.prod([mesh.shape[a] for a in ax]))
            assert dim % size == 0, (arch, leaf.shape, spec)

    jax.tree.map(check, shapes, specs,
                 is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x7b"])
def test_opt_specs_add_data_axis(arch):
    cfg = get_config(arch)
    mesh = _fake_mesh()
    shapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0),
                                                  cfg))
    opt_shapes = jax.eval_shape(adamw_init, shapes)
    pspecs = Sh.param_specs(shapes, cfg, mesh)
    ospecs = Sh.opt_state_specs(opt_shapes, pspecs, cfg, mesh)
    n_data = sum(1 for s in jax.tree.leaves(
        ospecs["m"], is_leaf=lambda x: isinstance(x, P))
        if "data" in jax.tree_util.tree_leaves(tuple(s)))
    assert n_data > 0, "ZeRO-1 data-axis sharding never applied"


def test_moe_expert_sharding_rules():
    mesh = _fake_mesh()
    qcfg = get_config("qwen3-moe-30b-a3b")     # 128 experts: expert-parallel
    mcfg = get_config("mixtral-8x7b")          # 8 experts: shard d_ff
    qshapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0),
                                                   qcfg))
    qspecs = Sh.param_specs(qshapes, qcfg, mesh)
    q_w = qspecs["stages"][0]["pos0"]["ffn"]["moe"]["w_gate"]
    assert tuple(q_w) [1] == "model"          # (layer, E, D, F): E sharded
    mshapes = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0),
                                                   mcfg))
    mspecs = Sh.param_specs(mshapes, mcfg, mesh)
    m_w = mspecs["stages"][0]["pos0"]["ffn"]["moe"]["w_gate"]
    assert tuple(m_w)[-1] == "model"          # d_ff sharded instead


def test_production_mesh_shapes():
    # uses the 1-device CPU? make_production_mesh needs 256 devices — only
    # verify the *spec* of the function via AbstractMesh equivalence here.
    import inspect
    src = inspect.getsource(make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src.replace("'", '"')


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-370m",
                                  "mixtral-8x7b", "whisper-base"])
def test_train_step_runs_on_host_mesh(arch, key):
    """The exact train_step the dry-run lowers, executed for real on a tiny
    config and 1x1 mesh; loss must be finite and params must change."""
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh()
    shape = ShapeConfig("tiny", seq_len=32, global_batch=2, kind="train")
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        step = ST.make_train_step(cfg, mesh, num_micro=2, q_chunk=16,
                                  lr=1e-3)
        params = T.init_params(key, cfg)
        opt = adamw_init(params)
        from repro.launch.input_specs import train_batch_specs
        specs = train_batch_specs(cfg, shape)
        batch = {k: jnp.zeros(v.shape, v.dtype) if v.dtype == jnp.int32
                 else jax.random.normal(key, v.shape, v.dtype)
                 for k, v in specs.items()}
        params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    diff = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32))))
               for a, b in zip(jax.tree.leaves(params),
                               jax.tree.leaves(params2)))
    assert diff > 0.0


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma3-12b"])
def test_serve_step_runs_on_host_mesh(arch, key):
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh()
    with mesh:
        serve = ST.make_serve_step(cfg)
        params = T.init_params(key, cfg)
        state = T.init_decode_state(params, cfg, 2, 16, jnp.float32)
        tok = jnp.zeros((2, 1), jnp.int32)
        nxt, state = jax.jit(serve)(params, state, tok)
    assert nxt.shape == (2, 1)
    assert int(state["index"]) == 1
