"""Uplink-compression extension tests (DESIGN.md §5b / paper §5: gradient
compression is orthogonal to scheduling and combinable): property tests of
the top-k / dense-int8 round-trips, the analytic bytes-ratio accounting,
and the compression-aware link-budget coupling
(`LinkConfig` -> `uplink_bytes_ratio` -> `LinkBudget.need_up`)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fl.compression import (compress_int8, compress_topk_int8,
                                  decompress, decompress_int8, roundtrip,
                                  roundtrip_int8, uplink_bytes_ratio)


def test_roundtrip_keeps_topk_exactly_shaped(key):
    tree = {"a": jax.random.normal(key, (64, 32)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (100,))}
    out, ratio = roundtrip(tree, k_frac=0.25)
    assert ratio > 3.0
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail("shape"), tree, out)


def test_topk_preserves_largest_entries(key):
    x = {"w": jnp.asarray([10.0, -8.0, 0.1, 0.01, 6.0, -0.2, 0.0, 0.3])}
    out, _ = roundtrip(x, k_frac=0.375)   # keep 3 of 8
    w = np.asarray(out["w"])
    # the three largest-magnitude entries survive (int8-quantized)
    np.testing.assert_allclose(w[[0, 1, 4]], [10.0, -8.0, 6.0], rtol=0.02)
    assert (w[[2, 3, 5, 6, 7]] == 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(8, 200), st.floats(0.05, 1.0))
def test_quantization_error_bounded(n, k_frac):
    rng = np.random.default_rng(n)
    x = {"w": jnp.asarray(rng.normal(0, 1, n).astype(np.float32))}
    out, ratio = roundtrip(x, k_frac=float(k_frac))
    w0, w1 = np.asarray(x["w"]), np.asarray(out["w"])
    kept = w1 != 0
    # int8 symmetric quantization: relative error on kept entries < 1%
    # of the max magnitude
    assert np.abs(w1[kept] - w0[kept]).max() <= \
        np.abs(w0).max() / 127.0 + 1e-6
    assert ratio >= 0.79   # int8+idx vs f32 never worse than 0.8x


# ---------------------------------------------------------------------------
# property tests: round-trip guarantees and bytes accounting


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 150), st.floats(0.05, 1.0), st.integers(0, 10_000))
def test_topk_keeps_exact_index_set(n, k_frac, seed):
    """With distinct magnitudes the kept index set is exactly the top-k by
    |value|, and the dequantization error on kept entries is <= scale/2
    (round-to-nearest)."""
    rng = np.random.default_rng(seed)
    mags = rng.permutation(np.arange(1, n + 1)).astype(np.float32)
    vals = mags * rng.choice([-1.0, 1.0], n).astype(np.float32)
    comp, b_c, b_r = compress_topk_int8({"w": jnp.asarray(vals)},
                                        float(k_frac))
    leaf = comp["w"]
    k = max(1, int(n * k_frac))
    expect = set(np.argsort(np.abs(vals))[-k:].tolist())
    assert set(np.asarray(leaf.indices).tolist()) == expect
    assert leaf.values.shape == (k,)
    assert b_c == k * 5 and b_r == n * 4
    scale = float(leaf.scale)
    deq = np.asarray(leaf.values, np.float32) * scale
    err = np.abs(deq - vals[np.asarray(leaf.indices)])
    assert err.max() <= scale / 2 + 1e-6


@settings(max_examples=15, deadline=None)
@given(st.integers(8, 200))
def test_topk_bytes_monotone_in_k_frac(n):
    """Measured compressed bytes grow monotonically in k_frac, the raw
    bytes don't move, and the analytic ratio tracks the same ordering."""
    x = {"w": jnp.asarray(
        np.random.default_rng(n).normal(size=n).astype(np.float32))}
    fracs = [0.05, 0.1, 0.25, 0.5, 0.75, 1.0]
    sizes = [compress_topk_int8(x, f)[1:] for f in fracs]
    comp_bytes = [c for c, _ in sizes]
    assert all(a <= b for a, b in zip(comp_bytes, comp_bytes[1:]))
    assert all(r == n * 4 for _, r in sizes)
    ratios = [uplink_bytes_ratio(f) for f in fracs]
    assert all(a < b for a, b in zip(ratios, ratios[1:]))


@settings(max_examples=20, deadline=None)
@given(st.integers(10, 120), st.integers(0, 10_000))
def test_roundtrip_idempotent_on_already_sparse(n, seed):
    """decompress∘compress is exact on an update that already went through
    one round-trip: the surviving entries are int8-representable at the
    same scale, so a second pass reproduces them bit-for-bit."""
    rng = np.random.default_rng(seed)
    k = max(1, int(n * 0.25))
    dense = np.zeros(n, np.float32)
    pos = rng.choice(n, size=k, replace=False)
    dense[pos] = rng.normal(0, 1, k).astype(np.float32)
    once = roundtrip({"w": jnp.asarray(dense)}, 0.25)[0]
    twice = roundtrip(once, 0.25)[0]
    np.testing.assert_array_equal(np.asarray(once["w"]),
                                  np.asarray(twice["w"]))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 200), st.integers(0, 10_000))
def test_int8_dense_roundtrip(n, seed):
    """Dense int8: shape-preserving, error <= scale/2 on EVERY entry,
    bytes = one per entry + a per-leaf scale, and idempotent on an
    already-quantized tree."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 3, n).astype(np.float32)
    comp, b_c, b_r = compress_int8({"w": jnp.asarray(x)})
    assert b_r == 4 * n and b_c == n + 4
    deq = np.asarray(decompress_int8(comp)["w"])
    assert deq.shape == x.shape
    scale = float(comp["w"].scale)
    assert np.abs(deq - x).max() <= scale / 2 + 1e-6
    again = np.asarray(roundtrip_int8({"w": jnp.asarray(deq)})[0]["w"])
    np.testing.assert_array_equal(again, deq)


def test_uplink_bytes_ratio_accounting():
    """The analytic ratio matches the measured per-leaf accounting in the
    large-leaf limit: 5 bytes per kept top-k entry, 1 byte per dense-int8
    entry, 4 bytes per raw f32 entry; off = 1.0."""
    assert uplink_bytes_ratio() == 1.0
    assert uplink_bytes_ratio(0.0, int8=False) == 1.0
    assert uplink_bytes_ratio(None) == 1.0
    assert uplink_bytes_ratio(0.1) == pytest.approx(0.125)
    assert uplink_bytes_ratio(0.0, int8=True) == 0.25
    x = {"w": jnp.zeros(4000)}
    _, b_c, b_r = compress_topk_int8(x, 0.1)
    assert b_c / b_r == pytest.approx(uplink_bytes_ratio(0.1))
    _, b_c8, b_r8 = compress_int8(x)
    assert b_c8 / b_r8 == pytest.approx(uplink_bytes_ratio(int8=True),
                                        rel=0.01)


def test_simulation_with_compressed_uplink():
    from repro.core import connectivity as CN
    from repro.core.scheduler import make_scheduler
    from repro.data.fmow import FmowSpec, SyntheticFmow
    from repro.data.partition import iid_partition
    from repro.data.pipeline import make_clients
    from repro.fl.adapters import MlpFmowAdapter
    from repro.fl.simulation import run_simulation
    spec = CN.ConstellationSpec(num_satellites=16)
    C = CN.connectivity_sets(spec, days=0.5)
    data = SyntheticFmow(FmowSpec(num_train=800, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(iid_partition(800, 16, 0)))
    res = run_simulation(C, adapter, make_scheduler("fedbuff", M=4),
                         eval_every=16, max_windows=48, uplink_topk=0.25)
    assert res.num_global_updates >= 1
    assert res.accuracy[-1] > 1.0 / 62.0   # still learns through compression


# ---------------------------------------------------------------------------
# config validation and the compression-aware link budget


def test_engine_config_uplink_topk_validated():
    from repro.fl.engine import EngineConfig
    for bad in (-0.2, 1.0001, 7.0):
        with pytest.raises(ValueError,
                           match=r"EngineConfig\.uplink_topk must be in "
                                 r"\(0, 1\]"):
            EngineConfig(uplink_topk=bad)
    # the off sentinels and the bounds stay constructible (the engine
    # resolves None -> 0.0 through dataclasses.replace, which re-runs
    # __post_init__)
    assert EngineConfig().uplink_topk is None
    assert EngineConfig(uplink_topk=0.0).uplink_topk == 0.0
    assert EngineConfig(uplink_topk=1.0).uplink_topk == 1.0


def test_link_config_uplink_topk_validated():
    from repro.fl.api import LinkConfig
    with pytest.raises(ValueError,
                       match=r"LinkConfig\.uplink_topk must be in \[0, 1\], "
                             r"got 1\.5"):
        LinkConfig(uplink_topk=1.5)
    with pytest.raises(ValueError,
                       match=r"LinkConfig\.uplink_topk must be >= 0"):
        LinkConfig(uplink_topk=-0.1)
    assert LinkConfig(uplink_topk=1.0).uplink_topk == 1.0


def _payload_experiment(*, topk=0.0, int8=False, fast_loop=True,
                        train_topk=None):
    from repro.fl.api import (AdapterConfig, ConstellationConfig,
                              DatasetConfig, FLExperiment, LinkConfig,
                              SchedulerConfig)
    from repro.fl.engine import EngineConfig
    return FLExperiment(
        constellation=ConstellationConfig(num_satellites=10, days=0.25),
        dataset=DatasetConfig(num_train=240, num_val=80),
        adapter=AdapterConfig(kind="transformer",
                              params={"d_model": 16, "num_layers": 1,
                                      "num_heads": 2, "num_kv_heads": 1,
                                      "d_ff": 32}),
        scheduler=SchedulerConfig(kind="fedbuff", params={"M": 2}),
        train=EngineConfig(eval_every=12, max_windows=24, local_steps=2,
                           fast_loop=fast_loop, uplink_topk=train_topk),
        link=LinkConfig(uplink_topk=topk, uplink_int8=int8,
                        uplink_mbps=20.0, downlink_mbps=100.0,
                        model_mb=300.0, gs_capacity=1),
    )


def test_compression_off_bit_identical_both_strategies():
    """`uplink_topk=None` (unset) and an explicit 0.0 must produce the
    same trajectory as each other, bit for bit, under the fast loop AND
    the per-window host loop — the parity contract of the payload path."""
    from repro.fl.api import Federation

    def run(topk_train, fast):
        fed = Federation.from_experiment(_payload_experiment(
            fast_loop=fast, train_topk=topk_train))
        eng = fed.engine()
        res = eng.run()
        return eng, res

    e_ref, r_ref = run(None, True)
    for topk_train, fast in ((0.0, True), (None, False), (0.0, False)):
        e, r = run(topk_train, fast)
        assert np.array_equal(e.version, e_ref.version)
        assert np.array_equal(e.pending, e_ref.pending)
        assert np.array_equal(e.buffered_base, e_ref.buffered_base)
        assert e.ig == e_ref.ig
        assert r.accuracy == r_ref.accuracy
        assert r.val_loss == r_ref.val_loss
        assert r.summary() == r_ref.summary()
        for a, b in zip(jax.tree.leaves(e.params),
                        jax.tree.leaves(e_ref.params)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_compression_reduces_need_up():
    """A non-trivial compression ratio rescales the effective uplink
    payload: 300 MB at 20 Mbit/s needs 2 contact units raw, 1 at top-k
    0.25 (ratio 0.3125) or dense int8 (0.25); the downlink (full model)
    is untouched."""
    from repro.fl.api import Federation
    f_raw = Federation.from_experiment(_payload_experiment())
    f_tk = Federation.from_experiment(_payload_experiment(topk=0.25))
    f_i8 = Federation.from_experiment(_payload_experiment(int8=True))
    assert f_raw.link_budget.need_up == 2
    assert f_tk.link_budget.need_up == 1
    assert f_i8.link_budget.need_up == 1
    assert f_raw.link_budget.need_dn == f_tk.link_budget.need_dn == 1
    # train-level EngineConfig.uplink_topk wins over LinkConfig's
    f_override = Federation.from_experiment(
        _payload_experiment(topk=0.25, train_topk=1.0))
    assert f_override.link_budget.need_up == 3   # ratio 1.25 -> 375 MB
