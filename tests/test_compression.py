"""Uplink-compression extension tests (DESIGN.md §5b / paper §5: gradient
compression is orthogonal to scheduling and combinable)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.fl.compression import compress_topk_int8, decompress, roundtrip


def test_roundtrip_keeps_topk_exactly_shaped(key):
    tree = {"a": jax.random.normal(key, (64, 32)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (100,))}
    out, ratio = roundtrip(tree, k_frac=0.25)
    assert ratio > 3.0
    jax.tree.map(lambda a, b: None if a.shape == b.shape else
                 pytest.fail("shape"), tree, out)


def test_topk_preserves_largest_entries(key):
    x = {"w": jnp.asarray([10.0, -8.0, 0.1, 0.01, 6.0, -0.2, 0.0, 0.3])}
    out, _ = roundtrip(x, k_frac=0.375)   # keep 3 of 8
    w = np.asarray(out["w"])
    # the three largest-magnitude entries survive (int8-quantized)
    np.testing.assert_allclose(w[[0, 1, 4]], [10.0, -8.0, 6.0], rtol=0.02)
    assert (w[[2, 3, 5, 6, 7]] == 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(8, 200), st.floats(0.05, 1.0))
def test_quantization_error_bounded(n, k_frac):
    rng = np.random.default_rng(n)
    x = {"w": jnp.asarray(rng.normal(0, 1, n).astype(np.float32))}
    out, ratio = roundtrip(x, k_frac=float(k_frac))
    w0, w1 = np.asarray(x["w"]), np.asarray(out["w"])
    kept = w1 != 0
    # int8 symmetric quantization: relative error on kept entries < 1%
    # of the max magnitude
    assert np.abs(w1[kept] - w0[kept]).max() <= \
        np.abs(w0).max() / 127.0 + 1e-6
    assert ratio >= 0.79   # int8+idx vs f32 never worse than 0.8x


def test_simulation_with_compressed_uplink():
    from repro.core import connectivity as CN
    from repro.core.scheduler import make_scheduler
    from repro.data.fmow import FmowSpec, SyntheticFmow
    from repro.data.partition import iid_partition
    from repro.data.pipeline import make_clients
    from repro.fl.adapters import MlpFmowAdapter
    from repro.fl.simulation import run_simulation
    spec = CN.ConstellationSpec(num_satellites=16)
    C = CN.connectivity_sets(spec, days=0.5)
    data = SyntheticFmow(FmowSpec(num_train=800, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(iid_partition(800, 16, 0)))
    res = run_simulation(C, adapter, make_scheduler("fedbuff", M=4),
                         eval_every=16, max_windows=48, uplink_topk=0.25)
    assert res.num_global_updates >= 1
    assert res.accuracy[-1] > 1.0 / 62.0   # still learns through compression
