"""Per-architecture smoke tests (deliverable f): a REDUCED variant of every
assigned architecture (<=2 layers, d_model<=512, <=4 experts) runs one
forward and one train step on CPU; output shapes and no-NaN asserted."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_configs
from repro.models import transformer as T
from repro.optim import adamw_init, adamw_update, apply_updates

ARCHS = [a for a in list_configs() if a != "densenet-fl"]


def _batch_for(cfg, key, B=2, S=64):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision":
        batch = {
            "tokens": tokens[:, :S - cfg.num_image_tokens],
            "labels": tokens[:, :S - cfg.num_image_tokens],
            "image_embeds": jax.random.normal(
                key, (B, cfg.num_image_tokens, 1024), jnp.float32),
        }
    if cfg.is_encoder_decoder:
        batch = {
            "tokens": tokens[:, :cfg.decoder_prompt],
            "labels": tokens[:, :cfg.decoder_prompt],
            "frames": jax.random.normal(key, (B, cfg.encoder_seq,
                                              cfg.d_model), jnp.float32),
        }
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_no_nan(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(key, cfg)
    batch = _batch_for(cfg, key)
    logits, aux = T.forward(params, batch, cfg, q_chunk=32, remat=False)
    expect_s = batch["tokens"].shape[1]
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(jnp.asarray(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step_no_nan(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(key, cfg)
    batch = _batch_for(cfg, key)

    def loss_fn(p):
        logits, aux = T.forward(p, batch, cfg, q_chunk=32, remat=False)
        return T.lm_loss(logits, batch["labels"]) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    opt = adamw_init(params)
    upd, opt = adamw_update(grads, opt, params, lr=1e-3)
    params2 = apply_updates(params, upd)
    loss2 = loss_fn(params2)
    assert jnp.isfinite(loss2)
    gn = sum(jnp.sum(jnp.abs(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert float(gn) > 0.0, "gradients all zero"


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(key, cfg)
    kwargs = {}
    if cfg.is_encoder_decoder:
        kwargs["enc_frames"] = jax.random.normal(
            key, (2, cfg.encoder_seq, cfg.d_model), jnp.float32)
    state = T.init_decode_state(params, cfg, 2, 32, jnp.float32, **kwargs)
    logits, state2 = T.decode_step(params, jnp.zeros((2, 1), jnp.int32),
                                   state, cfg)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert int(state2["index"]) == 1
