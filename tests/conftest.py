import importlib.util
import os
import sys

import jax
import numpy as np
import pytest

# Optional dev dependency: the property tests use hypothesis when present,
# and fall back to a deterministic sampler (tests/_hypothesis_fallback.py)
# when it isn't installed — the suite must collect on a bare container.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _path = os.path.join(os.path.dirname(__file__),
                         "_hypothesis_fallback.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real CPU device; only the dry-run uses 512
# virtual devices (see repro/launch/dryrun.py).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
