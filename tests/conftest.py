import jax
import numpy as np
import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real CPU device; only the dry-run uses 512
# virtual devices (see repro/launch/dryrun.py).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
