"""Protocol-semantics tests for the staleness/idleness machinery, including
a transcription of the paper's illustrative example (Fig. 3 / Table 1) and
hypothesis property tests on the invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import staleness as SS

# ---------------------------------------------------------------------------
# The paper's illustrative example (Appendix A): 3 satellites, 9 windows.
# Figure 3 connectivity (green circles): satellite k connected at windows:
#   SA1: 0, 2, 4, 6, 8
#   SA2: 1, 3, 5, 7
#   SA3: 0, 7
PAPER_C = np.zeros((9, 3), bool)
PAPER_C[[0, 2, 4, 6, 8], 0] = True
PAPER_C[[1, 3, 5, 7], 1] = True
PAPER_C[[0, 7], 2] = True


def _run(a):
    # cold start, as in the paper's example: satellites first download at
    # their first contact and upload at a later one
    state = SS.init_state(3)
    st_, ig, infos = SS.simulate_window(jnp.asarray(PAPER_C),
                                        jnp.asarray(a, np.int32), state,
                                        jnp.int32(0))
    return int(ig), {k: np.asarray(v) for k, v in infos.items()}


def test_paper_example_async():
    """Async FL (Fig. 3b / Table 1): aggregate whenever the buffer is
    non-empty. Paper: 8 aggregated gradients, max staleness 5 (SA3 at i=7),
    zero idle connections. (Our protocol has no training-latency windows —
    see DESIGN.md §7 — so the per-staleness split differs slightly, but the
    totals and the extreme match.)"""
    a = np.ones(9, np.int32)
    ig, infos = _run(a)
    hist = infos["hist"].sum(axis=0)
    assert infos["n_idle"].sum() == 0
    assert infos["max_staleness"].max() == 5   # SA3: base v0, 5 aggs later
    assert hist.sum() == 8                      # Table 1 Async total


def test_paper_example_sync():
    """Sync FL (Fig. 3a): single aggregation once all three have uploaded
    (at i=7); all gradients have staleness 0; 3 aggregated gradients
    (Table 1 Sync). Idle connections: 4 under our latency-free protocol —
    SA1 at i=4,6 and SA2 at i=5,7 (the paper counts 5 with its
    training-latency diagram)."""
    a = np.zeros(9, np.int32)
    a[7] = 1
    ig, infos = _run(a)
    assert ig == 1
    hist = infos["hist"].sum(axis=0)
    assert hist[0] == 3 and hist[1:].sum() == 0
    assert infos["n_idle"].sum() == 4


def test_paper_example_fedbuff_like():
    """FedBuff M=2 (Fig. 4): aggregate when the buffer reaches 2; the paper
    reports max staleness dropping from 5 (async) to 2 and no idle
    connections. Under our latency-free protocol the same schedule yields 6
    aggregated gradients (every upload used, none idle)."""
    a = np.zeros(9, np.int32)
    a[[3, 5, 7]] = 1
    ig, infos = _run(a)
    assert infos["n_idle"].sum() == 0
    assert infos["max_staleness"].max() == 2   # SA3 base v0 aggregated at ig=2
    assert infos["hist"].sum() == 6            # every upload aggregated


# ---------------------------------------------------------------------------
# Property tests (hypothesis)


@st.composite
def _scenario(draw):
    K = draw(st.integers(2, 8))
    I = draw(st.integers(4, 20))
    C = np.array(draw(st.lists(st.lists(st.booleans(), min_size=K,
                                        max_size=K), min_size=I,
                               max_size=I)), bool)
    a = np.array(draw(st.lists(st.integers(0, 1), min_size=I, max_size=I)),
                 np.int32)
    return C, a


@settings(max_examples=60, deadline=None)
@given(_scenario())
def test_invariants(scn):
    C, a = scn
    I, K = C.shape
    state = SS.bootstrap_state(K)
    st_, ig, infos = SS.simulate_window(jnp.asarray(C), jnp.asarray(a),
                                        state, jnp.int32(0))
    hist = np.asarray(infos["hist"])
    n_agg = np.asarray(infos["n_aggregated"])
    # 1. ig advances at most once per scheduled aggregation (empty-buffer
    # aggregations are no-ops)
    assert int(ig) <= int(a.sum())
    # 2. per-window histogram totals equal n_aggregated
    assert (hist.sum(axis=1) == n_agg).all()
    # 3. each satellite contributes at most one gradient per aggregation
    assert (n_agg <= K).all()
    # 4. gradients aggregated never exceed number of uploads possible
    assert n_agg.sum() <= C.sum()
    # 5. staleness bounded by number of prior aggregations
    msv = np.asarray(infos["max_staleness"])
    prior = np.concatenate([[0], np.cumsum(a)[:-1]])
    assert (msv <= prior).all()


@settings(max_examples=30, deadline=None)
@given(_scenario())
def test_aggregate_every_window_zero_staleness_beyond_one(scn):
    """If we aggregate every window, staleness of an upload is bounded by
    the number of aggregations since the satellite's last download."""
    C, _ = scn
    I, K = C.shape
    a = np.ones(I, np.int32)
    state = SS.bootstrap_state(K)
    _, _, infos = SS.simulate_window(jnp.asarray(C), jnp.asarray(a), state,
                                     jnp.int32(0))
    # with aggregation every window, idle connections are impossible
    assert np.asarray(infos["n_idle"]).sum() == 0


def test_compensation_function():
    s = jnp.arange(10)
    c = SS.staleness_compensation(s, alpha=0.5)
    assert float(c[0]) == 1.0
    assert (np.diff(np.asarray(c)) < 0).all()   # monotonically decreasing
