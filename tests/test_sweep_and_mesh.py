"""Parity gates for the two PR-8 execution strategies: whole-experiment
sweeps batched into one dispatch (`repro.fl.sweep`) and satellite-axis
sharding across a device mesh (`repro.core.mesh` + engine `mesh=`).

Both are pure performance features, so every test here is an identity
test: the batched/sharded trajectory must be bit-identical to the
sequential single-device one — the same standard
tests/test_protocol_lockstep.py holds the fast loop to."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isl as ISL
from repro.core import mesh as MM
from repro.core.faults import FaultConfig, fault_trace, random_churn
from repro.core.scheduler import FedSpaceScheduler, make_scheduler
from repro.core.utility import RandomForestRegressor
from repro.fl.engine import EngineConfig, SimulationEngine
from repro.fl.sweep import sweep_engines
from tests.test_protocol_lockstep import (ScriptedScheduler, _StubAdapter,
                                          _budget)


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_caches():
    """This module lands at the tail of tier-1 and compiles several large
    scan programs; after ~500 tests the accumulated in-process XLA
    executables can crash CPU backend_compile (observed as a segfault
    only in full-suite runs, never standalone). Start from a clean
    compile cache so the module's programs build in a fresh compiler
    state."""
    jax.clear_caches()


def _engine(C, sched, *, budget=None, isl=None, faults=None, mesh=None,
            **cfg):
    I, K = C.shape
    return SimulationEngine(C, _StubAdapter(K), sched,
                            EngineConfig(eval_every=I + 1, **cfg),
                            link_budget=budget, isl=isl, faults=faults,
                            mesh=mesh)


def _assert_same_outcome(eng, res, out):
    """Sequential engine (ran) vs one SweepOutcome: full protocol parity."""
    s = out.result
    np.testing.assert_array_equal(eng.version, out.version)
    np.testing.assert_array_equal(eng.pending, out.pending)
    np.testing.assert_array_equal(eng.buffered_base, out.buffered)
    assert eng.ig == out.ig
    assert res.staleness_hist.tolist() == s.staleness_hist.tolist()
    assert res.idle_connections == s.idle_connections
    assert res.total_connections == s.total_connections
    assert res.num_global_updates == s.num_global_updates
    assert res.num_aggregated_gradients == s.num_aggregated_gradients
    assert res.windows_run == s.windows_run


@st.composite
def _variants(draw):
    """2-4 scripted variants of independent shapes: same-shape ones land
    in one vmapped group, odd ones in their own — both paths must agree
    with the sequential reference either way."""
    out = []
    for _ in range(draw(st.integers(2, 4))):
        K = draw(st.integers(2, 6))
        I = draw(st.integers(4, 16))
        C = np.array(draw(st.lists(st.lists(st.booleans(), min_size=K,
                                            max_size=K), min_size=I,
                                   max_size=I)), bool)
        a = np.array(draw(st.lists(st.integers(0, 1), min_size=I,
                                   max_size=I)), np.int32)
        out.append((C, a))
    return out


@settings(max_examples=15, deadline=None)
@given(_variants())
def test_sweep_lockstep_with_sequential_runs(vs):
    """The batched dispatch replays tests/test_protocol_lockstep.py's
    reference: each variant of a random scripted grid comes back
    bit-identical to its own sequential engine run."""
    seq = []
    for C, a in vs:
        eng = _engine(C, ScriptedScheduler(a))
        seq.append((eng, eng.run()))
    outs = sweep_engines(
        [_engine(C, ScriptedScheduler(a)) for C, a in vs])
    for (eng, res), out in zip(seq, outs):
        _assert_same_outcome(eng, res, out)


def _rand_world(K=10, I=48, seed=0):
    rng = np.random.default_rng(seed)
    return rng.random((I, K)) < 0.3


def test_sweep_odd_variant_count_mixed_schedulers():
    """5 variants (not a power of two) interleaving scheduler kinds over
    one world: grouping must split them by indicator and stitch results
    back in input order."""
    C = _rand_world()
    scheds = [make_scheduler("fedbuff", M=3), make_scheduler("sync"),
              make_scheduler("fedbuff", M=6), make_scheduler("periodic",
                                                             period=4),
              make_scheduler("async")]
    seq = []
    for s in scheds:
        eng = _engine(C, s)
        seq.append((eng, eng.run()))
    outs = sweep_engines([_engine(C, s) for s in scheds])
    schemes = [o.result.scheme for o in outs]
    assert schemes == ["fedbuff", "sync", "fedbuff", "periodic", "async"]
    for (eng, res), out in zip(seq, outs):
        _assert_same_outcome(eng, res, out)


def test_sweep_optional_columns_present_and_absent():
    """One batch mixing every optional-column layout — plain geometry,
    link budget, fault masks, sink relaying, gossip — against each
    variant's sequential run."""
    K, I = 12, 48
    C = _rand_world(K, I, seed=1)
    grants = (np.random.default_rng(2).integers(1, 4, C.shape)
              .astype(np.int32)) * C
    budget = _budget(C, grants, 2, 1)
    trace = fault_trace(
        FaultConfig(deorbit=random_churn(K, I, 0.3, seed=3)), I, K=K)
    isl = ISL.ISL(ISL.identity_topology(K), relay_windows=2, epoch=12)

    def build():
        return [
            _engine(C, make_scheduler("fedbuff", M=4)),
            _engine(C, make_scheduler("fedbuff", M=4), budget=budget),
            _engine(C, make_scheduler("fedbuff", M=4), faults=trace),
            _engine(C, make_scheduler("fedbuff", M=4), budget=budget,
                    faults=trace),
            _engine(C, make_scheduler("intra_plane", M=4), isl=isl),
            _engine(C, make_scheduler("isl_async", M=2), isl=isl),
            _engine(C, make_scheduler("isl_async", M=2), isl=isl,
                    faults=trace),
        ]

    seq = []
    for eng in build():
        seq.append((eng, eng.run()))
    outs = sweep_engines(build())
    for (eng, res), out in zip(seq, outs):
        _assert_same_outcome(eng, res, out)


def test_inherently_sequential_variants_raise():
    """FedSpace replans mid-run (finite device-plan horizon) and host-only
    schedulers have no plan at all: both must fail loudly, not diverge
    silently."""
    K, I = 4, 16
    C = _rand_world(K, I, seed=4)
    reg = RandomForestRegressor(n_trees=2, max_depth=3).fit(
        np.random.default_rng(0).random((30, 11)).astype(np.float32),
        np.random.default_rng(1).random(30).astype(np.float32))
    fs = FedSpaceScheduler(reg, I0=8, num_candidates=8)
    with pytest.raises(ValueError, match="not sweepable"):
        sweep_engines([_engine(C, fs)])
    a = np.ones(I, np.int32)
    with pytest.raises(ValueError, match="not sweepable"):
        sweep_engines([_engine(C, ScriptedScheduler(a, device=False))])


def test_sweep_rejects_stop_at_target():
    C = _rand_world(6, 16, seed=5)
    eng = _engine(C, make_scheduler("sync"), target_acc=0.5)
    with pytest.raises(ValueError, match="not sweepable"):
        sweep_engines([eng])


def test_mesh_single_device_identity():
    """`mesh=sim_mesh()` on however many devices this process has (1 under
    plain pytest) must not change a single bit of the trajectory — the
    padding/sharding plumbing itself is exercised even at mesh size 1."""
    K, I = 10, 48
    C = _rand_world(K, I, seed=6)
    grants = (np.random.default_rng(7).integers(1, 4, C.shape)
              .astype(np.int32)) * C
    trace = fault_trace(
        FaultConfig(deorbit=random_churn(K, I, 0.25, seed=8)), I, K=K)
    mesh = MM.sim_mesh()
    for kw in ({}, {"budget": _budget(C, grants, 2, 1)},
               {"faults": trace}):
        ref = _engine(C, make_scheduler("fedbuff", M=4), **kw)
        ref_res = ref.run()
        shd = _engine(C, make_scheduler("fedbuff", M=4), mesh=mesh, **kw)
        shd_res = shd.run()
        np.testing.assert_array_equal(ref.version, shd.version)
        np.testing.assert_array_equal(ref.pending, shd.pending)
        np.testing.assert_array_equal(ref.buffered_base, shd.buffered_base)
        assert ref.ig == shd.ig
        assert ref_res.staleness_hist.tolist() == \
            shd_res.staleness_hist.tolist()
        assert ref_res.idle_connections == shd_res.idle_connections
        assert ref_res.total_connections == shd_res.total_connections


_MESH8_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import sys
sys.path.insert(0, "src")
import jax.numpy as jnp
from repro.core import mesh as MM
from repro.core.connectivity import LinkBudget
from repro.core.faults import FaultConfig, fault_trace, random_churn
from repro.core.scheduler import make_scheduler
from repro.core.search import score_candidates
from repro.core import staleness as SS
from repro.fl.engine import EngineConfig, SimulationEngine

class _StubAdapter:          # protocol-only runs: training is a no-op
    def __init__(self, K): self.clients = list(range(K))
    def init(self, key): return {"w": jnp.zeros((2,))}
    def loss(self, params, batch):
        return jnp.sum(params["w"]) * 0.0 + jnp.sum(batch) * 0.0
    def client_batch(self, ci, round_rng, batch_size, num_batches):
        return jnp.zeros((num_batches, 1))
    def accuracy(self, params): return 0.0
    def val_loss(self, params): return 0.0

def _budget(C, grants, need_up, need_dn):
    return LinkBudget(visible=C, served=C,
                      assign=np.where(C, 0, -1).astype(np.int32),
                      grants=grants, need_up=need_up, need_dn=need_dn)

K, I = 36, 48                      # 36 % 8 != 0: exercises K padding
rng = np.random.default_rng(0)
C = rng.random((I, K)) < 0.3
grants = rng.integers(1, 4, C.shape).astype(np.int32) * C
trace = fault_trace(
    FaultConfig(deorbit=random_churn(K, I, 0.25, seed=1)), I, K=K)
mesh = MM.sim_mesh()
assert MM.mesh_size(mesh) == 8, MM.mesh_size(mesh)

def run(mesh, **kw):
    eng = SimulationEngine(C, _StubAdapter(K), make_scheduler("fedbuff",
                                                              M=6),
                           EngineConfig(eval_every=I + 1),
                           mesh=mesh, **kw)
    res = eng.run()
    return eng, res

for kw in ({}, {"link_budget": _budget(C, grants, 2, 1)},
           {"faults": trace}):
    ref, ref_res = run(None, **kw)
    shd, shd_res = run(mesh, **kw)
    assert np.array_equal(ref.version, shd.version)
    assert np.array_equal(ref.pending, shd.pending)
    assert np.array_equal(ref.buffered_base, shd.buffered_base)
    assert ref.ig == shd.ig
    assert ref_res.staleness_hist.tolist() == \
        shd_res.staleness_hist.tolist()
    assert ref_res.idle_connections == shd_res.idle_connections

from repro.core.utility import RandomForestRegressor
reg = RandomForestRegressor(n_trees=2, max_depth=3).fit(
    rng.random((30, 11)).astype(np.float32),
    rng.random(30).astype(np.float32))
cand = rng.integers(0, 2, (16, 24)).astype(np.int32)
state = SS.bootstrap_state(K)
s1 = score_candidates(cand, C[:24], state, 0, reg, 0.5, s_max=8)
s2 = score_candidates(cand, C[:24], state, 0, reg, 0.5, s_max=8,
                      mesh=mesh)
assert np.array_equal(np.asarray(s1), np.asarray(s2))
print("MESH8_OK")
"""


def test_mesh_8_device_subprocess():
    """Forced 8-device CPU mesh in a fresh subprocess (device count locks
    at first jax init): sharded engine runs — including a K (36) that the
    mesh does not divide — and the sharded eq.-13 scorer must be
    bit-identical to single-device."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MESH8_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MESH8_OK" in r.stdout
