"""Tests for the `repro.fl` experiment layer: SimulationEngine parity with
the legacy `run_simulation` loop (transcribed below verbatim from the
pre-engine implementation), registry round-trips, the declarative
`FLExperiment`/`Federation` builder, and callbacks."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointStore
from repro.core import connectivity as CN
from repro.core import staleness as SS
from repro.core.aggregation import apply_aggregation
from repro.core.scheduler import Scheduler, make_scheduler
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition
from repro.data.pipeline import make_clients
from repro.fl.adapters import MlpFmowAdapter
from repro.fl.api import (AdapterConfig, ConstellationConfig, DatasetConfig,
                          FLExperiment, Federation, PartitionConfig,
                          SchedulerConfig)
from repro.fl.callbacks import (Callback, EarlyStopCallback,
                                JsonlMetricsCallback)
from repro.fl.client import make_client_update
from repro.fl.engine import EngineConfig, SimulationEngine
from repro.fl.registry import (Registry, SCHEDULERS, register_scheduler)
from repro.fl.simulation import run_simulation


@pytest.fixture(scope="module")
def tiny_world():
    spec = CN.ConstellationSpec(num_satellites=16)
    C = CN.connectivity_sets(spec, days=1.0)
    data = SyntheticFmow(FmowSpec(num_train=800, num_val=200))
    adapter = MlpFmowAdapter(data, make_clients(iid_partition(800, 16, 0)))
    return C, adapter


# ---------------------------------------------------------------------------
# engine parity vs the legacy loop


def _legacy_run_simulation(C, adapter, scheduler, *, local_steps=4,
                           client_lr=0.05, server_lr=1.0, alpha=0.5,
                           eval_every=8, target_acc=None, max_windows=None,
                           s_max=8, seed=0, stop_at_target=True):
    """The pre-engine `run_simulation` body (seed commit), kept here as the
    reference trajectory the engine must reproduce bit-for-bit."""
    from repro.fl.engine import SimResult
    I, K = C.shape
    if max_windows:
        I = min(I, max_windows)
    scheduler.reset()
    params = adapter.init(jax.random.PRNGKey(seed))
    client_update = make_client_update(adapter, local_steps=local_steps,
                                      lr=client_lr, trainable_mask=None)
    store = CheckpointStore(keep_in_memory=s_max + 26)
    store.put(0, params)
    ig = 0
    version = np.zeros(K, np.int64)
    pending = np.zeros(K, np.int64)
    buffered_base = np.full(K, -1, np.int64)
    res = SimResult(scheme=scheduler.name, target_acc=target_acc)
    res.staleness_hist = np.zeros(s_max + 1, np.int64)
    status = float(adapter.val_loss(params))
    for i in range(I):
        conn = np.flatnonzero(C[i])
        for k in conn:
            res.total_connections += 1
            if pending[k] >= 0:
                buffered_base[k] = pending[k]
                pending[k] = -1
            elif version[k] == ig:
                res.idle_connections += 1
        n_buf = int((buffered_base >= 0).sum())
        state = SS.SatState(jnp.asarray(version, jnp.int32),
                            jnp.asarray(pending, jnp.int32),
                            jnp.asarray(buffered_base, jnp.int32))
        a = scheduler.decide(i, n_in_buffer=n_buf, K=K, state=state, ig=ig,
                             connectivity=C, status=status)
        if a and n_buf > 0:
            ks = np.flatnonzero(buffered_base >= 0)
            stal = ig - buffered_base[ks]
            updates = [client_update(store.get(int(buffered_base[k])),
                                     int(k), round_rng=i) for k in ks]
            stack = jax.tree.map(lambda *xs: jnp.stack(xs), *updates)
            params = apply_aggregation(params, stack, jnp.asarray(stal),
                                       alpha=alpha, server_lr=server_lr)
            ig += 1
            store.put(ig, params)
            refs = [v for v in np.concatenate([pending, buffered_base])
                    if v >= 0]
            store.prune(min(refs) if refs else ig)
            res.num_global_updates += 1
            res.num_aggregated_gradients += len(ks)
            np.add.at(res.staleness_hist, np.clip(stal, 0, s_max), 1)
            buffered_base[:] = -1
        for k in conn:
            if version[k] < ig:
                version[k] = ig
                pending[k] = ig
        res.windows_run = i + 1
        if (i + 1) % eval_every == 0 or i == I - 1:
            acc = adapter.accuracy(params)
            status = float(adapter.val_loss(params))
            res.accuracy.append(acc)
            res.val_loss.append(status)
            res.eval_windows.append(i)
            if (target_acc is not None and acc >= target_acc
                    and res.time_to_target_days is None):
                res.time_to_target_days = res.days(i)
                if stop_at_target:
                    break
    return res


@pytest.mark.parametrize("scheme,kw", [("sync", {}), ("async", {}),
                                       ("fedbuff", {"M": 4})])
def test_engine_matches_legacy_trajectory(tiny_world, scheme, kw):
    C, adapter = tiny_world
    ref = _legacy_run_simulation(C, adapter, make_scheduler(scheme, **kw),
                                 eval_every=16, max_windows=64)
    new = run_simulation(C, adapter, make_scheduler(scheme, **kw),
                         eval_every=16, max_windows=64)
    assert new.summary() == ref.summary()
    assert new.accuracy == ref.accuracy
    assert new.val_loss == ref.val_loss
    assert new.eval_windows == ref.eval_windows
    assert new.windows_run == ref.windows_run


def test_engine_overridable_step(tiny_world):
    """Scenario variants subclass the engine and override one protocol
    step — here, a lossy downlink that never delivers to satellite 0."""
    C, adapter = tiny_world

    class LossyDownlink(SimulationEngine):
        def on_downloads(self, i, conn):
            super().on_downloads(i, np.asarray(conn) & (
                np.arange(self.K) != 0))

    eng = LossyDownlink(C, adapter, make_scheduler("async"),
                        EngineConfig(eval_every=16, max_windows=48))
    res = eng.run()
    assert res.num_global_updates > 0
    assert eng.version[0] == 0          # never downloaded a newer model


# ---------------------------------------------------------------------------
# registries


def test_registry_roundtrip_and_helpful_keyerror():
    reg = Registry("widget")

    @reg.register("spinny")
    class Spinny:
        def __init__(self, speed=1):
            self.speed = speed

    assert "spinny" in reg and reg.names() == ["spinny"]
    assert reg.build("spinny", speed=3).speed == 3
    with pytest.raises(KeyError) as ei:
        reg.get("spiny")
    assert "spinny" in str(ei.value) and "widget" in str(ei.value)


def test_make_scheduler_lists_registered_names():
    with pytest.raises(KeyError) as ei:
        make_scheduler("does-not-exist")
    msg = str(ei.value)
    for name in ("sync", "async", "fedbuff", "fedspace", "periodic"):
        assert name in msg


def test_builtin_schedulers_registered_and_decide():
    assert {"sync", "async", "fedbuff", "fedspace",
            "periodic"} <= set(SCHEDULERS.names())
    sched = make_scheduler("fedbuff", M=3)
    assert sched.decide(0, n_in_buffer=3) and \
        not sched.decide(0, n_in_buffer=2)


def test_custom_scheduler_end_to_end(tiny_world):
    """Acceptance: a new scheduler plugs in via decorator + name only —
    no engine/scheduler-module edits."""
    C, adapter = tiny_world

    @register_scheduler("every3-test")
    class EveryThird(Scheduler):
        name = "every3-test"

        def decide(self, i, *, n_in_buffer, **_):
            return n_in_buffer > 0 and i % 3 == 2

    exp = FLExperiment(
        constellation=ConstellationConfig(num_satellites=16, days=1.0),
        dataset=DatasetConfig(num_train=800, num_val=200),
        scheduler=SchedulerConfig(kind="every3-test"),
        train=EngineConfig(eval_every=16, max_windows=48),
    )
    res = Federation.from_experiment(exp).run()
    assert res.scheme == "every3-test"
    assert res.num_global_updates > 0


# ---------------------------------------------------------------------------
# the declarative builder


def test_federation_wiring():
    exp = FLExperiment(
        constellation=ConstellationConfig(num_satellites=12, days=0.5),
        dataset=DatasetConfig(num_train=600, num_val=150),
        partition=PartitionConfig(kind="noniid"),
        adapter=AdapterConfig(kind="mlp", params={"hidden": 24}),
        scheduler=SchedulerConfig(kind="fedbuff", params={"M": 4}),
        train=EngineConfig(eval_every=16, max_windows=32),
        seed=3,
    )
    fed = Federation.from_experiment(exp)
    assert fed.spec.num_satellites == 12
    assert fed.C.shape[1] == 12
    assert len(fed.adapter.clients) == 12
    assert fed.adapter.hidden == 24
    assert fed.scheduler.name == "fedbuff"
    # all samples covered by the partition
    covered = np.sort(np.concatenate(
        [c.indices for c in fed.adapter.clients]))
    assert (covered == np.arange(600)).all()
    res = fed.run()
    assert res.windows_run == 32
    # same world, different policy — adapter/data shared, not rebuilt
    fed2 = fed.with_scheduler("async")
    assert fed2.adapter is fed.adapter
    assert fed2.run().scheme == "async"


def test_federation_auto_repeat_connectivity():
    exp = FLExperiment(
        constellation=ConstellationConfig(num_satellites=8, days=0.25),
        dataset=DatasetConfig(num_train=200, num_val=50),
        scheduler=SchedulerConfig(kind="async"),
        train=EngineConfig(eval_every=16, max_windows=60,
                           repeat_connectivity=0),
    )
    fed = Federation.from_experiment(exp)
    assert fed.C.shape[0] == 24                       # 0.25 days of windows
    eng = fed.engine()
    assert eng.num_windows == 60                      # C tiled to cover


# ---------------------------------------------------------------------------
# callbacks


def test_jsonl_and_early_stop_callbacks(tiny_world, tmp_path):
    C, adapter = tiny_world
    path = str(tmp_path / "metrics.jsonl")

    class NeverImproves(EarlyStopCallback):
        def on_eval(self, engine, window, metrics):
            super().on_eval(engine, window,
                            {**metrics, "accuracy": 0.0})

    eng = SimulationEngine(
        C, adapter, make_scheduler("async"),
        EngineConfig(eval_every=4, max_windows=96),
        callbacks=[JsonlMetricsCallback(path),
                   NeverImproves(patience=2)])
    res = eng.run()
    assert res.windows_run < 96                       # stopped early
    lines = [json.loads(l) for l in open(path)]
    events = [l["event"] for l in lines]
    assert events[0] == "run_begin" and events[-1] == "run_end"
    evals = [l for l in lines if l["event"] == "eval"]
    assert len(evals) == len(res.accuracy)
    assert evals[0]["accuracy"] == res.accuracy[0]


def test_aggregate_hook_sees_updates(tiny_world):
    C, adapter = tiny_world
    seen = []

    class Spy(Callback):
        def on_aggregate_end(self, engine, window, info):
            seen.append(info)

    res = SimulationEngine(C, adapter, make_scheduler("fedbuff", M=4),
                           EngineConfig(eval_every=16, max_windows=48),
                           callbacks=[Spy()]).run()
    assert len(seen) == res.num_global_updates
    assert sum(s["n_aggregated"] for s in seen) == \
        res.num_aggregated_gradients
    assert seen[-1]["ig"] == res.num_global_updates
