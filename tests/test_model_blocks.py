"""Block-level correctness: chunked attention vs naive, SSD vs sequential
recurrence, RG-LRU scan vs step oracle, MoE dispatch vs dense oracle, and
train-vs-decode consistency per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import attention as A
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models import transformer as T


def test_chunked_attention_matches_naive(key):
    cfg = get_config("qwen3-8b").reduced()
    B, Sq, H, hd = 2, 64, cfg.num_heads, cfg.resolved_head_dim
    K = cfg.num_kv_heads
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, K, hd))
    full = A._attend_full(q, k, v, cfg, q_chunk=Sq)      # single chunk
    chunked = A._attend_full(q, k, v, cfg, q_chunk=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               atol=2e-5)


def test_local_attention_equals_masked_full(key):
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b").reduced(),
                              window_size=16)
    B, Sq = 2, 64
    H, hd, K = cfg.num_heads, cfg.resolved_head_dim, cfg.num_kv_heads
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, Sq, K, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, Sq, K, hd))
    local = A._attend_local(q, k, v, cfg, q_chunk=16)
    # oracle: full attention with explicit window mask
    from repro.kernels.flash_attention.ref import attention_ref
    ref = attention_ref(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                        jnp.moveaxis(v, 2, 1), causal=True,
                        window=cfg.window_size)
    np.testing.assert_allclose(np.asarray(local),
                               np.asarray(jnp.moveaxis(ref, 1, 2)),
                               atol=2e-5)


def test_ssd_chunked_matches_sequential(key):
    cfg = get_config("mamba2-370m").reduced()
    params = S.ssm_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 9), (2, 64, cfg.d_model))
    y_chunked = S.ssm_apply(params, x, cfg)
    y_seq = x + 0  # residual handled inside both paths identically?
    ref = S.ssm_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_rglru_scan_matches_stepwise(key):
    cfg = get_config("recurrentgemma-9b").reduced()
    params = R.rglru_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 48, cfg.d_model))
    y = R.rglru_apply(params, x, cfg)
    ref = R.rglru_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-4)


def test_moe_matches_dense_oracle_no_drop(key):
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              moe_capacity_factor=8.0)
    p = M.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 4), (2, 32, cfg.d_model))
    y, aux = M.moe_apply(p, x, cfg)
    ref = M.moe_apply_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=3e-5)
    assert float(aux) > 0.0


def test_moe_aux_loss_bounds(key):
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    p = M.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 5), (2, 64, cfg.d_model))
    _, aux = M.moe_apply(p, x, cfg)
    # Switch aux loss >= 1 at perfect balance cannot go below k/E * E = k...
    # practical bound: positive and finite
    assert 0.0 < float(aux) < cfg.num_experts


@pytest.mark.parametrize("arch", ["qwen3-8b", "gemma3-12b", "mamba2-370m",
                                  "recurrentgemma-9b", "h2o-danube-1.8b"])
def test_train_decode_consistency(arch, key):
    cfg = get_config(arch).reduced()
    params = T.init_params(key, cfg)
    B, Sq = 2, 48
    tokens = jax.random.randint(key, (B, Sq), 0, cfg.vocab_size)
    full, _ = T.forward(params, {"tokens": tokens}, cfg, q_chunk=16,
                        remat=False)
    state = T.init_decode_state(params, cfg, B, Sq, jnp.float32)
    dec = jax.jit(lambda p, t, s: T.decode_step(p, t, s, cfg))
    outs = []
    for t in range(Sq):
        lg, state = dec(params, tokens[:, t:t + 1], state)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec_logits),
                               atol=5e-4, rtol=1e-3)


def test_moe_drop_semantics_no_slot_corruption(key):
    """Regression for the capacity-overflow bug found in §Perf: dropped
    tokens must NOT overwrite slot 0 of their expert. With a tiny capacity,
    kept tokens' outputs must agree across all three dispatch paths."""
    import dataclasses
    from repro.configs.base import get_config
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              moe_capacity_factor=0.5)   # force drops
    p = M.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 11), (4, 32, cfg.d_model))
    y1, _ = M.moe_apply(p, x, cfg, groups=1)
    y2, _ = M.moe_apply(p, x, cfg, groups=4)
    assert not bool(jnp.isnan(y1).any()) and not bool(jnp.isnan(y2).any())
    # the ungrouped path with global capacity 2x the per-group capacity
    # processes a superset of tokens; both must stay finite and bounded
    assert float(jnp.max(jnp.abs(y1))) < 1e3


def test_moe_ep_matches_dense(key):
    """Expert-parallel shard_map path vs the dense oracle on a 4x2 mesh."""
    import dataclasses, os
    from repro.configs.base import get_config
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 virtual devices (run standalone)")
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              moe_capacity_factor=8.0)
    p = M.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 12), (4, 32, cfg.d_model))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh:
        y, _ = jax.jit(lambda p_, x_: M.moe_apply_ep(p_, x_, cfg, mesh)
                       )(p, x)
    ref = M.moe_apply_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=3e-5)
