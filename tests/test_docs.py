"""Docs cannot rot silently: every repo path referenced in `docs/` or the
README must exist, and every `path::name` anchor must point at a function,
class, or method that is still defined in that file."""
import os
import re

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

DOC_FILES = sorted(
    [os.path.join("docs", f) for f in os.listdir(os.path.join(ROOT, "docs"))
     if f.endswith(".md")] + ["README.md"])

# `src/...py::Name` or `src/...py::Class.method` inside backticks
ANCHOR_RE = re.compile(r"`([\w./-]+\.py)::([\w.]+)`")
# bare repo-relative paths inside backticks
PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|docs)/[\w./-]+\.\w+|"
    r"(?:README|ROADMAP|PAPER|PAPERS|SNIPPETS|CHANGES)\.md|"
    r"BENCH_hotpaths\.json)`")


def _read(rel):
    with open(os.path.join(ROOT, rel)) as f:
        return f.read()


def _anchors():
    out = []
    for doc in DOC_FILES:
        text = _read(doc)
        out += [(doc, path, name) for path, name in ANCHOR_RE.findall(text)]
    assert out, "no path::name anchors found — checker regex rotted?"
    return out


def _paths():
    out = []
    for doc in DOC_FILES:
        text = _read(doc)
        out += [(doc, p) for p in PATH_RE.findall(text)]
        out += [(doc, p) for p, _ in ANCHOR_RE.findall(text)]
    return out


@pytest.mark.parametrize("doc,path", sorted(set(_paths())))
def test_referenced_path_exists(doc, path):
    assert os.path.exists(os.path.join(ROOT, path)), \
        f"{doc} references missing path {path}"


@pytest.mark.parametrize("doc,path,name", sorted(set(_anchors())))
def test_anchor_resolves(doc, path, name):
    """The anchored name must be defined in the anchored file — `def name`
    / `class name` for top-level names, the method def for `Cls.method`."""
    full = os.path.join(ROOT, path)
    assert os.path.exists(full), f"{doc}: anchor file {path} missing"
    src = _read(path)
    leaf = name.split(".")[-1]
    pat = re.compile(rf"^\s*(?:def|class)\s+{re.escape(leaf)}\b|"
                     rf"^{re.escape(leaf)}\s*[:=]", re.MULTILINE)
    assert pat.search(src), \
        f"{doc}: anchor {path}::{name} does not resolve ({leaf} not " \
        f"defined in {path})"
    if "." in name:   # Cls.method: the class must exist too
        cls = name.split(".")[0]
        assert re.search(rf"^\s*class\s+{re.escape(cls)}\b", src,
                         re.MULTILINE), \
            f"{doc}: anchor class {cls} not defined in {path}"


def test_no_orphan_docs():
    """Every page in docs/ must be reachable from docs/index.md — a page
    nobody links is a page nobody reads, and it rots."""
    index = _read("docs/index.md")
    orphans = [os.path.basename(d) for d in DOC_FILES
               if d.startswith("docs/")
               and os.path.basename(d) != "index.md"
               and os.path.basename(d) not in index]
    assert not orphans, f"docs not linked from docs/index.md: {orphans}"


def test_cross_doc_links_resolve():
    """Every `docs/*.md` reference inside a doc page must point at a page
    that exists (stale cross-links are the docs equivalent of a dangling
    pointer)."""
    ref_re = re.compile(r"docs/[\w-]+\.md")
    stale = []
    for doc in DOC_FILES:
        for ref in set(ref_re.findall(_read(doc))):
            if not os.path.exists(os.path.join(ROOT, ref)):
                stale.append((doc, ref))
    assert not stale, f"stale cross-doc links: {stale}"


def test_equation_map_is_complete():
    """The docs system must keep covering the paper constructs the issue
    tracker promised: eq. 2, eq. 4, eq. 13, and Algorithm 1."""
    pages = {os.path.basename(d) for d in DOC_FILES}
    assert {"eq2_connectivity.md", "eq4_aggregation.md", "eq13_search.md",
            "algorithm1_transitions.md", "architecture.md",
            "index.md"} <= pages
    index = _read("docs/index.md")
    for page in ("eq2_connectivity", "eq4_aggregation", "eq13_search",
                 "algorithm1_transitions"):
        assert page in index, f"index.md no longer links {page}"
