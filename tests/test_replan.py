"""Incremental replanning service (`repro.fl.replan`): the delta-window
path must be *bit-identical* to a full rescan of the same pool from the
caller's state, every invalidation rule must actually fire, and routing
the FedSpace scheduler through a service must not change a single
trajectory bit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import staleness as SS
from repro.core.search import (random_candidates, scan_candidates,
                               score_candidates, select_candidate)
from repro.core.utility import (MLPRegressor, RandomForestRegressor,
                                featurize, n_features, transfer_ready,
                                transfer_report)
from repro.fl.replan import ReplanService

S_MAX = 8


@pytest.fixture(scope="module", autouse=True)
def _drop_jit_caches():
    """This module compiles far more distinct executables than any other
    (the bucket ladder alone is a dozen shapes per jitted entry point);
    leaving them live for the rest of the suite has crashed XLA's CPU
    compiler deep in later, unrelated tests. Drop them on the way out."""
    yield
    jax.clear_caches()


def _forest(seed=0, n_trees=4):
    rng = np.random.default_rng(seed)
    hists = rng.integers(0, 20, (150, S_MAX + 1)).astype(np.float32)
    X = featurize(hists, 1.0)
    s = np.arange(S_MAX + 1, dtype=np.float32)
    y = ((hists * (1.0 - 0.1 * s)).sum(1)
         / np.maximum(hists.sum(1), 1.0)).astype(np.float32)
    return RandomForestRegressor(n_trees=n_trees, max_depth=4,
                                 seed=seed).fit(X, y)


def _world(K=24, T=64, p=0.3, seed=0):
    rng = np.random.default_rng(seed)
    C = rng.random((T, K)) < p
    state = jax.tree.map(np.asarray, SS.bootstrap_state(K))
    return C, state


def _advance(state, ig, conn, bit):
    """Realize one window of the true protocol (the engine's view)."""
    st, g, _ = SS.step(jax.tree.map(jnp.asarray, state), jnp.int32(ig),
                       jnp.asarray(conn), jnp.asarray(bool(bit)),
                       s_max=S_MAX, collect="none")
    return jax.tree.map(np.asarray, st), int(g)


# ---------------------------------------------------------------------------
# the tentpole invariant: delta == full rescan, bit for bit


@pytest.mark.parametrize("explicit_maintain", [True, False])
def test_delta_selection_bit_identical_to_full_rescan(explicit_maintain):
    """Across a stream of consecutive replans, every answer — delta or
    full — must equal `score_candidates` + `select_candidate` on the
    service's live pool from the caller's state. With
    `explicit_maintain=False` the service must fold the deferred frontier
    advance into the next answer itself."""
    rf = _forest()
    C, state = _world()
    svc = ReplanService(rf, I0=8, num_candidates=64, s_max=S_MAX, seed=7,
                        min_pool=8)
    ig, status = 0, 3.0
    modes = []
    for i in range(6):
        Cw = C[i:i + 8]
        plan = svc.replan(i, Cw, state, ig, status,
                          rng=np.random.default_rng(100 + i))
        modes.append(svc.last_mode)
        pool = svc.pool
        scores = score_candidates(pool, Cw, state, ig, rf, status,
                                  s_max=S_MAX)
        assert np.array_equal(plan, pool[select_candidate(pool, scores)])
        if explicit_maintain:
            svc.maintain()
        state, ig = _advance(state, ig, C[i], plan[0])
    assert modes[0] == "full" and "delta" in modes
    assert svc.stats["delta"] == modes.count("delta")


def test_scan_candidates_scores_match_score_candidates():
    """The cache-collecting scan twin must reproduce `score_candidates`
    bit for bit (same narrowed simulator, same device reduction)."""
    rf = _forest()
    C, state = _world(K=16, T=16)
    cands = random_candidates(np.random.default_rng(3), 10, 2, 5, 48)
    ref = np.asarray(score_candidates(cands, C[:10], state, 0, rf, 2.0,
                                      s_max=S_MAX))
    got, art = scan_candidates(cands, C[:10], state, 0, rf, 2.0,
                               s_max=S_MAX)
    assert np.array_equal(ref, np.asarray(got))
    assert art["win_util"].shape == (48, 10)
    assert art["end_ig"].shape == (48,)
    # per-event utilities land exactly at each candidate's event windows
    assert np.array_equal(art["win_util"] != 0.0,
                          (art["win_util"] * cands) != 0.0)


def test_pool_decays_and_winner_survives():
    rf = _forest()
    C, state = _world()
    svc = ReplanService(rf, I0=8, num_candidates=64, s_max=S_MAX, seed=7,
                        min_pool=4)
    ig = 0
    plan = svc.replan(0, C[0:8], state, ig, 1.0,
                      rng=np.random.default_rng(0))
    r0 = svc.pool.shape[0]
    state, ig = _advance(state, ig, C[0], plan[0])
    plan2 = svc.replan(1, C[1:9], state, ig, 1.0)
    assert svc.last_mode == "delta"
    assert svc.pool.shape[0] < r0           # survivors only
    # the previous winner's tail is still in the pool (it IS reality)
    assert any(np.array_equal(row[:7], plan[1:]) for row in svc.pool)
    assert plan2.shape == (8,)


# ---------------------------------------------------------------------------
# invalidation rules


def _primed(min_pool=4, K=24):
    """A service with a warm cache at window 0 plus the advanced state."""
    rf = _forest()
    C, state = _world(K=K)
    svc = ReplanService(rf, I0=8, num_candidates=64, s_max=S_MAX, seed=7,
                        min_pool=min_pool)
    plan = svc.replan(0, C[0:8], state, 0, 1.0,
                      rng=np.random.default_rng(0))
    state, ig = _advance(state, 0, C[0], plan[0])
    return svc, C, state, ig, plan


def test_invalidation_reasons_fire():
    svc, C, state, ig, plan = _primed()

    # non-consecutive window
    svc.replan(4, C[4:12], state, ig, 1.0, rng=np.random.default_rng(1))
    assert (svc.last_mode, svc.last_reason) == ("full", "window")

    # prime again, then: changed status invalidates every cached utility
    state2, ig2 = _advance(state, ig, C[4], svc.pool[0][0])
    svc.replan(5, C[5:13], state2, ig2, 9.0,
               rng=np.random.default_rng(2))
    assert (svc.last_mode, svc.last_reason) == ("full", "status")


def test_invalidation_horizon_and_connectivity():
    svc, C, state, ig, _ = _primed()
    svc.replan(1, C[1:7], state, ig, 1.0, rng=np.random.default_rng(1))
    assert (svc.last_mode, svc.last_reason) == ("full", "horizon")

    svc2, C2, state2, ig2, _ = _primed()
    Cw = C2[1:9].copy()
    Cw[2] = ~Cw[2]                          # overlap rows differ
    svc2.replan(1, Cw, state2, ig2, 1.0, rng=np.random.default_rng(1))
    assert (svc2.last_mode, svc2.last_reason) == ("full", "connectivity")


def test_invalidation_drift():
    """A caller whose state does not match the realized winner bit (e.g.
    an out-of-band aggregation) must force a full rescan."""
    svc, C, state, ig, plan = _primed()
    wrong_state, wrong_ig = _advance(state, ig, C[0], 1 - int(plan[0]))
    svc.replan(1, C[1:9], wrong_state, wrong_ig, 1.0,
               rng=np.random.default_rng(1))
    assert (svc.last_mode, svc.last_reason) == ("full", "drift")


def test_invalidation_link_view():
    svc, C, state, ig, _ = _primed()
    K = C.shape[1]
    # the gated rescan needs the in-progress-transfer column attached
    state = SS.SatState(state.version, state.pending, state.buffered,
                        np.zeros(K, np.int32), None)
    gate = SS.LinkGate(jnp.ones((8, K), jnp.int32), jnp.int32(1),
                       jnp.int32(1))
    svc.replan(1, C[1:9], state, ig, 1.0, link=gate,
               rng=np.random.default_rng(1))
    assert (svc.last_mode, svc.last_reason) == ("full", "link")


def test_external_invalidate_and_pool_floor():
    svc, C, state, ig, _ = _primed(min_pool=64)
    svc.invalidate("reset")
    assert svc.pool is None
    svc.replan(1, C[1:9], state, ig, 1.0, rng=np.random.default_rng(1))
    assert (svc.last_mode, svc.last_reason) == ("full", "cold")
    assert svc.stats["invalidated"]["reset"] == 1

    # min_pool=64 == R: the first consecutive request trips the floor
    state, ig = _advance(state, ig, C[1], svc.pool[0][0])
    svc.replan(2, C[2:10], state, ig, 1.0, rng=np.random.default_rng(2))
    assert (svc.last_mode, svc.last_reason) == ("full", "pool")


def test_transfer_ready_gatekeeps_service():
    class NoDevice:
        def predict(self, X):
            return np.zeros(len(X), np.float32)

    with pytest.raises(ValueError, match="transfer-ready"):
        ReplanService(NoDevice())

    rf = _forest()
    rf.n_features_ = 99                     # fitted at a different s_max
    with pytest.raises(ValueError, match="transfer-ready"):
        ReplanService(rf)


# ---------------------------------------------------------------------------
# forest transfer metadata


def test_fit_records_envelope_and_transfer_report():
    rf = _forest()
    assert rf.n_features_ == n_features(S_MAX)
    assert rf.feature_low_.shape == (n_features(S_MAX),)
    assert transfer_ready(rf, s_max=S_MAX)
    assert not transfer_ready(rf, s_max=4)  # width mismatch

    mlp = MLPRegressor(hidden=8, steps=5, seed=0).fit(
        np.random.default_rng(0).random((32, n_features(S_MAX))).astype(
            np.float32),
        np.zeros(32, np.float32))
    assert mlp.n_features_ == n_features(S_MAX)

    inside = transfer_report(rf, rf.feature_low_[None, :])
    assert inside["in_envelope"] == 1.0 and inside["out_features"] == []
    outside = transfer_report(rf, rf.feature_high_[None, :] + 1000.0)
    assert outside["in_envelope"] < 1.0 and outside["out_features"]
    assert outside["pred_finite"]           # trees saturate, never explode


# ---------------------------------------------------------------------------
# engine routing: a service-backed FedSpace run is the unrouted run


def test_fedspace_routed_through_service_is_bit_identical():
    from repro.fl.api import (AdapterConfig, ConstellationConfig,
                              DatasetConfig, FLExperiment, PartitionConfig,
                              SchedulerConfig)
    from repro.fl.api import Federation
    from repro.fl.engine import EngineConfig

    rf = _forest()
    W = 10
    exp = FLExperiment(
        constellation=ConstellationConfig(preset="starlink40", days=0.125),
        dataset=DatasetConfig(num_train=240, num_val=60),
        partition=PartitionConfig(kind="iid"),
        adapter=AdapterConfig(kind="mlp", params={"hidden": 8}),
        scheduler=SchedulerConfig(kind="fedspace",
                                  params={"regressor": rf, "I0": 5,
                                          "n_min": 1, "n_max": 2,
                                          "num_candidates": 16}),
        train=EngineConfig(max_windows=W, eval_every=W, local_steps=1,
                           batch_size=8))
    fed = Federation.from_experiment(exp)
    plain = fed.run()

    svc = ReplanService(rf, I0=5, num_candidates=16, s_max=S_MAX, seed=0)
    routed = fed.with_scheduler(SchedulerConfig(
        kind="fedspace",
        params={"regressor": rf, "I0": 5, "n_min": 1, "n_max": 2,
                "num_candidates": 16, "service": svc})).run()

    assert plain.accuracy == routed.accuracy
    assert plain.num_global_updates == routed.num_global_updates
    assert np.array_equal(plain.staleness_hist, routed.staleness_hist)
    assert plain.replan_stats is None
    assert routed.replan_stats is not None
    assert routed.replan_stats["full"] + routed.replan_stats["delta"] > 0
    assert routed.summary()["replan_stats"] == routed.replan_stats


def test_scheduler_service_knob_mismatch_rejected():
    from repro.core.scheduler import make_scheduler
    rf = _forest()
    svc = ReplanService(rf, I0=6, num_candidates=32, s_max=S_MAX)
    with pytest.raises(ValueError, match="service"):
        make_scheduler("fedspace", regressor=rf, I0=8, num_candidates=32,
                       service=svc)
