"""Shared adapter-contract tests.

The engine treats adapters as interchangeable: anything registered in
`repro.fl.registry.ADAPTERS` must provide init/apply/loss, deterministic
client batches (with the batched path bit-identical to per-client calls),
a deterministic eval batch, and updates whose pytree matches the
parameter pytree. These tests run the same contract over EVERY registered
adapter — MLP, the paper's DenseNet, and the transformer payload — so a
new adapter gets the full battery by registering (and adding its small
test config to `_PARAMS` below).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.fl.adapters  # noqa: F401 — registers the built-in adapters
from repro.data.fmow import FmowSpec, SyntheticFmow
from repro.data.partition import iid_partition
from repro.data.pipeline import make_clients
from repro.fl.client import make_batched_client_update
from repro.fl.registry import ADAPTERS

K = 6

# one deliberately tiny configuration per registered adapter; the pin
# test below forces additions here when a new adapter registers
_PARAMS = {
    "mlp": {"hidden": 16},
    # channel counts must stay divisible by the group-norm group count (8)
    "densenet": {"growth": 8, "blocks": (1, 1), "stem": 8, "val_n": 64},
    "transformer": {"d_model": 16, "num_layers": 1, "num_heads": 2,
                    "num_kv_heads": 1, "d_ff": 32},
}


def test_every_registered_adapter_is_covered():
    assert set(ADAPTERS.names()) == set(_PARAMS), (
        "a registered adapter has no contract-test config; add a tiny "
        "_PARAMS entry in tests/test_adapters_contract.py")


@pytest.fixture(scope="module")
def world():
    data = SyntheticFmow(FmowSpec(num_train=240, num_val=80))
    clients = make_clients(iid_partition(data.spec.num_train, K, 0))
    return data, clients


@pytest.fixture(scope="module", params=sorted(_PARAMS))
def adapter(request, world):
    data, clients = world
    return ADAPTERS.build(request.param, data, clients,
                          **_PARAMS[request.param])


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# --------------------------------------------------------------------------
# client batches


def test_client_batch_many_bit_identical_to_per_client(adapter):
    """The stacked fast-path batch must reproduce the sequential
    `client_batch` calls bit for bit for every included row — the engine's
    seed-trajectory guarantee rests on this."""
    for round_rng in (3, 17):
        stacked, rows = adapter.client_batch_many(list(range(K)), round_rng,
                                                  16, 2)
        assert rows == sorted(rows)
        assert set(rows) <= set(range(K))
        assert len(rows) > 0
        M = len(rows)
        for leaf in jax.tree.leaves(stacked):
            assert leaf.shape[0] == M
        for pos, cid in enumerate(rows):
            single = adapter.client_batch(cid, round_rng, 16, 2)
            assert single is not None
            got = jax.tree.map(lambda s: s[pos], stacked)
            assert _tree_equal(got, single)


def test_client_batch_grouping_is_deterministic(adapter):
    a = adapter.client_batch_many(list(range(K)), 11, 16, 2)
    b = adapter.client_batch_many(list(range(K)), 11, 16, 2)
    assert a[1] == b[1]
    assert _tree_equal(a[0], b[0])


# --------------------------------------------------------------------------
# evaluation


def test_eval_batch_deterministic_and_labeled(adapter):
    X1, y1 = adapter.eval_batch(64)
    X2, y2 = adapter.eval_batch(64)
    assert np.array_equal(np.asarray(X1), np.asarray(X2))
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert jnp.issubdtype(y1.dtype, jnp.integer)
    assert X1.shape[0] == y1.shape[0] <= 64


def test_accuracy_and_val_loss_are_finite(adapter):
    params = adapter.init(jax.random.PRNGKey(0))
    acc = adapter.accuracy(params, 64)
    vl = adapter.val_loss(params, 64)
    assert 0.0 <= acc <= 1.0
    assert np.isfinite(vl)
    # evaluation is pure: same params, same numbers
    assert adapter.accuracy(params, 64) == acc
    assert adapter.val_loss(params, 64) == vl


# --------------------------------------------------------------------------
# update pytrees


def test_batched_update_matches_param_pytree(adapter):
    """Client updates are deltas over the parameter pytree: identical
    treedef, and per-leaf shapes/dtypes with the stacked leading axis M —
    what the staleness aggregation and the compression roundtrip both
    assume."""
    params = adapter.init(jax.random.PRNGKey(1))
    mask = (adapter.trainable_mask(params)
            if hasattr(adapter, "trainable_mask") else None)
    if mask is not None:
        assert (jax.tree.structure(mask) == jax.tree.structure(params))
    update_many = make_batched_client_update(
        adapter, local_steps=2, lr=0.1, trainable_mask=mask)
    stacked, rows = adapter.client_batch_many(list(range(K)), 5, 16, 2)
    u = update_many(params, stacked)
    assert jax.tree.structure(u) == jax.tree.structure(params)
    M = len(rows)
    for du, p in zip(jax.tree.leaves(u), jax.tree.leaves(params)):
        assert du.shape == (M,) + p.shape
        assert du.dtype == p.dtype
        assert np.isfinite(np.asarray(du)).all()
