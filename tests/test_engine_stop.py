"""`request_stop` granularity and callback consistency under mid-run
interruption.

The fast loop advances whole jitted chunks (up to 128 windows) on device
before the per-window callbacks run on host; a stop requested from a
callback must nevertheless freeze the run exactly one window later — the
engine replays the chunk prefix to un-advance the state (see
`SimulationEngine._run_chunk`). These tests pin that latency contract for
both execution strategies, and check that the streaming callbacks
(JSONL metrics, checkpoint store) are left consistent by an early stop:
no torn/duplicate rows, run_end present, current checkpoint retrievable.
"""
import json

import jax
import numpy as np

from repro.fl.callbacks import Callback, JsonlMetricsCallback
from repro.fl.engine import EngineConfig, SimulationEngine
from tests.test_protocol_lockstep import ScriptedScheduler, _StubAdapter


class _StopAt(Callback):
    def __init__(self, window):
        self.window = window

    def on_window_end(self, engine, i):
        if i == self.window:
            engine.request_stop()


def _rng_world(I=96, K=6, seed=0):
    rng = np.random.default_rng(seed)
    C = rng.random((I, K)) < 0.3
    a = (rng.random(I) < 0.15).astype(np.int32)
    return C, a


def _engine(C, a, *, fast, callbacks=(), max_windows=None):
    I, K = C.shape
    return SimulationEngine(
        C, _StubAdapter(K), ScriptedScheduler(a, device=fast),
        EngineConfig(eval_every=1000, fast_loop=fast,
                     max_windows=max_windows), callbacks=list(callbacks))


def test_stop_latency_is_one_window_both_strategies():
    """A stop requested at window X mid-chunk leaves the engine in exactly
    the state of a reference run over X+1 windows — not advanced to the
    chunk boundary."""
    C, a = _rng_world()
    for stop_w in (0, 17, 37, 63):        # mid-chunk and boundary cases
        ref = _engine(C, a, fast=True, max_windows=stop_w + 1)
        ref.run()
        for fast in (True, False):
            eng = _engine(C, a, fast=fast, callbacks=[_StopAt(stop_w)])
            res = eng.run()
            assert res.windows_run == stop_w + 1, (fast, stop_w)
            np.testing.assert_array_equal(eng.version, ref.version)
            np.testing.assert_array_equal(eng.pending, ref.pending)
            np.testing.assert_array_equal(eng.buffered_base,
                                          ref.buffered_base)
            assert eng.ig == ref.ig
            assert res.total_connections == \
                int(C[:stop_w + 1].sum()), (fast, stop_w)


def test_stop_latency_with_faults_and_budget():
    """The chunk-prefix replay composes with the fault masks and link
    gating (the scan takes the same xs dict on the rescan)."""
    from repro.core.faults import FaultConfig, fault_trace
    from tests.test_protocol_lockstep import _budget

    C, a = _rng_world(seed=3)
    I, K = C.shape
    grants = (np.random.default_rng(1).integers(1, 4, C.shape)
              * C).astype(np.int32)
    budget = _budget(C, grants, 2, 1)
    trace = fault_trace(FaultConfig(deorbit=((1, 9),), launch=((1, 30),)),
                        I, K=K)
    stop_w = 41
    ref = SimulationEngine(C, _StubAdapter(K), ScriptedScheduler(a),
                           EngineConfig(eval_every=1000,
                                        max_windows=stop_w + 1),
                           link_budget=budget, faults=trace)
    ref.run()
    for fast in (True, False):
        eng = SimulationEngine(C, _StubAdapter(K),
                               ScriptedScheduler(a, device=fast),
                               EngineConfig(eval_every=1000,
                                            fast_loop=fast),
                               callbacks=[_StopAt(stop_w)],
                               link_budget=budget, faults=trace)
        res = eng.run()
        assert res.windows_run == stop_w + 1
        np.testing.assert_array_equal(eng.version, ref.version)
        np.testing.assert_array_equal(eng.pending, ref.pending)
        np.testing.assert_array_equal(eng.transfer_progress,
                                      ref.transfer_progress)
        assert eng.ig == ref.ig


def test_jsonl_stream_consistent_after_early_stop(tmp_path):
    """An early stop must leave the JSONL stream well-formed: every line
    parses, exactly one run_begin and one run_end, eval rows unique and
    in window order (no torn or duplicated rows)."""
    C, a = _rng_world()
    for fast in (True, False):
        path = tmp_path / f"metrics_{fast}.jsonl"
        eng = SimulationEngine(
            C, _StubAdapter(C.shape[1]), ScriptedScheduler(a, device=fast),
            EngineConfig(eval_every=8, fast_loop=fast),
            callbacks=[JsonlMetricsCallback(str(path)), _StopAt(43)])
        res = eng.run()
        assert res.windows_run == 44
        rows = [json.loads(line)
                for line in path.read_text().splitlines()]
        events = [r["event"] for r in rows]
        assert events[0] == "run_begin" and events[-1] == "run_end"
        assert events.count("run_begin") == events.count("run_end") == 1
        evals = [r["window"] for r in rows if r["event"] == "eval"]
        # evals at 8-window boundaries up to (not past) the stop point
        assert evals == [7, 15, 23, 31, 39]
        summary = rows[-1]
        assert summary["global_updates"] == res.num_global_updates


def test_checkpoint_store_retrievable_after_early_stop():
    """The device checkpoint ring stays consistent across an early stop:
    the current global version is retrievable and equals the engine's
    params under both strategies."""
    C, a = _rng_world(seed=7)
    for fast in (True, False):
        eng = _engine(C, a, fast=fast, callbacks=[_StopAt(50)])
        eng.run()
        assert eng.ig > 0          # the scenario aggregated before the stop
        stored = eng.store.get(eng.ig)
        for got, want in zip(jax.tree.leaves(stored),
                             jax.tree.leaves(eng.params)):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))
