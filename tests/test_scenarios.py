"""Constellation scenario suite: every registered preset must build a
valid periodic connectivity matrix (sane Fig.-2 statistics at any horizon)
and complete a short engine run under both a fixed-rule scheduler (sync)
and the FedSpace schedule search — i.e. any scheduler runs on any preset
through the declarative `FLExperiment` path."""
import json

import numpy as np
import pytest

from repro.core import connectivity as CN
from repro.core.utility import RandomForestRegressor, featurize
from repro.fl.api import (AdapterConfig, ConstellationConfig, DatasetConfig,
                          Federation, FLExperiment, PartitionConfig,
                          SchedulerConfig)
from repro.fl.engine import EngineConfig
from repro.fl.registry import CONSTELLATIONS

PRESETS = CONSTELLATIONS.names()
WINDOWS = 5


def _tiny_regressor(s_max=8):
    """Small fitted forest so FedSpace phase 2 runs without the expensive
    phase-1 pretrain/sampling pipeline."""
    rng = np.random.default_rng(0)
    hists = rng.integers(0, 20, (120, s_max + 1)).astype(np.float32)
    X = featurize(hists, 1.0)
    y = hists.sum(1).astype(np.float32)
    return RandomForestRegressor(n_trees=4, max_depth=3, seed=0).fit(X, y)


@pytest.fixture(scope="module")
def worlds():
    """One wired Federation per preset, shared across tests (connectivity
    propagation dominates the cost at K=1000)."""
    cache = {}

    def get(preset: str) -> Federation:
        if preset not in cache:
            exp = FLExperiment(
                constellation=ConstellationConfig(preset=preset,
                                                  days=0.125),
                dataset=DatasetConfig(num_train=240, num_val=60),
                partition=PartitionConfig(kind="iid"),
                adapter=AdapterConfig(kind="mlp", params={"hidden": 8}),
                scheduler=SchedulerConfig(kind="sync"),
                train=EngineConfig(max_windows=WINDOWS,
                                   eval_every=WINDOWS, local_steps=1,
                                   batch_size=8),
            )
            cache[preset] = Federation.from_experiment(exp)
        return cache[preset]

    return get


@pytest.mark.parametrize("preset", PRESETS)
def test_preset_builds_valid_connectivity(worlds, preset):
    fed = worlds(preset)
    spec, C = fed.spec, fed.C
    K = spec.num_satellites
    assert C.dtype == bool
    assert C.shape == (12, K)            # 0.125 days of 15-min windows
    if spec.shells:
        assert K == sum(s.num_satellites for s in spec.shells)

    st = CN.connectivity_stats(C)
    assert 0 <= st["ci_min"] <= st["ci_mean"] <= st["ci_max"] <= K
    assert st["ci_mean"] > 0             # the constellation does connect
    assert 0.0 <= st["nk_min"] <= st["nk_mean"] <= st["nk_max"] <= 96.0
    assert st["sizes"].shape == (C.shape[0],)
    assert st["contacts_per_day"].shape == (K,)

    summary = fed.connectivity_summary()
    assert set(summary) == {"ci_min", "ci_max", "ci_mean",
                            "nk_min", "nk_max", "nk_mean"}
    json.dumps(summary)                  # experiment-log serializable


@pytest.mark.parametrize("scheduler", ["sync", "fedspace"])
@pytest.mark.parametrize("preset", PRESETS)
def test_preset_completes_engine_run(worlds, preset, scheduler):
    fed = worlds(preset)
    if scheduler == "fedspace":
        fed = fed.with_scheduler(SchedulerConfig(
            kind="fedspace",
            params={"regressor": _tiny_regressor(), "I0": WINDOWS,
                    "n_min": 1, "n_max": 2, "num_candidates": 16}))
    res = fed.run()
    assert res.windows_run == WINDOWS
    assert res.total_connections > 0
    assert len(res.accuracy) == 1        # the eval_every=5 checkpoint
    if scheduler == "fedspace":
        # the searched schedule placed 1-2 aggregations in the horizon
        # (possibly coalesced by empty-buffer suppression, never more)
        assert 0 <= res.num_global_updates <= 2


@pytest.mark.parametrize("preset", ["starlink40", "starlink120",
                                    "starlink400"])
def test_forest_transfer_across_constellations(worlds, preset):
    """The replan-service handoff: a forest fitted at flock191 scale must
    be servable on every other constellation — the featurization is
    K-agnostic (width `n_features(s_max)` regardless of satellite count),
    features stay finite and in-range, and the transfer predicate/report
    agree (see repro.fl.replan)."""
    from repro.core.staleness import bootstrap_state, simulate_window
    from repro.core.utility import (n_features, transfer_ready,
                                    transfer_report)
    import jax.numpy as jnp

    s_max = 8
    rf = _tiny_regressor(s_max)           # "flock191 calibration" scale
    assert transfer_ready(rf, s_max=s_max)
    assert rf.n_features_ == n_features(s_max)

    fed = worlds(preset)
    C = fed.C
    K = C.shape[1]
    a = (np.arange(C.shape[0]) % 3 == 2).astype(np.int32)
    _, _, infos = simulate_window(jnp.asarray(C), jnp.asarray(a),
                                  bootstrap_state(K), jnp.int32(0),
                                  s_max=s_max, collect="hist")
    hists = np.asarray(infos["hist"]).astype(np.float32)
    X = featurize(hists, 1.0)
    assert X.shape == (C.shape[0], n_features(s_max))   # K-agnostic width
    assert np.isfinite(X).all()
    mean_stale = X[:, s_max + 3]
    assert ((mean_stale >= 0) & (mean_stale <= s_max)).all()

    rep = transfer_report(rf, X)
    assert rep["rows"] == C.shape[0] and rep["finite"]
    assert 0.0 <= rep["in_envelope"] <= 1.0
    assert rep["pred_finite"]             # saturating trees, never NaN


@pytest.mark.parametrize("preset", PRESETS)
def test_infer_n_range_valid_on_every_preset(worlds, preset):
    """`infer_n_range` must produce usable candidate-draw bounds from any
    preset's real connectivity statistics."""
    from repro.core.search import infer_n_range

    fed = worlds(preset)
    C = fed.C
    I0 = 24
    uploads = float(C.mean()) * C.shape[1]
    lo, hi = infer_n_range(_tiny_regressor(), uploads, I0, 1.0,
                           s_max=8, K=C.shape[1])
    assert 1 <= lo <= hi <= I0 // 2


def test_ground_networks_change_connectivity():
    dense = CN.connectivity_sets(
        CN.constellation_preset("starlink40"), days=0.125)
    sparse = CN.connectivity_sets(
        CN.constellation_preset("starlink40", ground="sparse1"),
        days=0.125)
    assert dense.sum() > sparse.sum()    # 12 stations see more than 1
    assert CN.constellation_preset(
        "starlink40", ground="sparse1").ground_stations == \
        CN.GROUND_NETWORKS["sparse1"]


def test_preset_overrides_and_errors():
    sp = CN.constellation_preset("flock191", min_elevation_deg=30.0)
    assert sp.min_elevation_deg == 30.0
    with pytest.raises(KeyError, match="registered constellation"):
        CN.constellation_preset("nope")
    with pytest.raises(KeyError, match="ground network"):
        CN.constellation_preset("flock191", ground="nope")
    with pytest.raises(ValueError, match="shells sum"):
        CN.satellite_elements(CN.ConstellationSpec(
            num_satellites=3, shells=(CN.Shell(2, 1, 5e5, 53.0),)))
